//! Paged KV arena parity + lifecycle, against the slab oracle and the
//! serving stack.  All on synthetic models/caches, so no
//! `make artifacts` is needed.
//!
//! The parity bar (ISSUE 4): forwards over the arena must be
//! bit-identical to the slab oracle under the same kernel, including
//! sequences spanning page boundaries (T = 63/64/65/129) and COW forks
//! mid-page; the scheduler must queue (not panic) when the arena runs
//! out of pages, and retire must make those pages reusable.
//!
//! The quantized bar (ISSUE 5): i8 paged attention stays within 1e-2
//! relative error of the f32 slab oracle across GQA configs and page
//! seams (u4 within a looser bound); tile-read round-trips stay within
//! the absmax step; COW on a shared quantized partial page preserves
//! the source's scales and bytes; mixed-precision arenas never alias;
//! and the prefix cache never forks pages across KV storage
//! precisions.

use std::sync::mpsc;
use std::time::Instant;

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::coordinator::batcher::Batcher;
use mobiquant::coordinator::controller::{ControllerConfig,
                                         ElasticController};
use mobiquant::coordinator::request::{Request, Response};
use mobiquant::coordinator::scheduler::Scheduler;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::attention::{append_kv_block, attention_block,
                                  AttnScratch, RopeCache};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::weights::ModelConfig;
use mobiquant::model::{KvArena, KvPrecision, KV_PAGE};
use mobiquant::util::prng::Pcg;

const TOL: f32 = 1e-4;

fn attn_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
            max_seq: usize) -> ModelConfig {
    ModelConfig {
        name: "arena".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads,
        d_ff: 16,
        max_seq_len: max_seq,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

/// The core storage-parity pin: identical K/V blocks appended to the
/// contiguous slab and to the paged arena (in uneven chunks that cross
/// page boundaries), then the *same* tiled kernel over both — outputs
/// must be exactly equal, at lengths straddling 1 and 2 page seams.
#[test]
fn arena_attention_bit_identical_to_slab_oracle() {
    let (n_heads, n_kv, hd) = (4usize, 2usize, 16usize);
    let max_seq = 3 * KV_PAGE;
    let cfg = attn_cfg(n_heads, n_kv, hd, max_seq);
    let d = cfg.d_model;
    let w = n_kv * hd;
    for &t in &[63usize, 64, 65, 129] {
        let mut rng = Pcg::new(300 + t as u64);
        let k_block = rng.normal_vec(t * w, 1.0);
        let v_block = rng.normal_vec(t * w, 1.0);
        let mut rope = RopeCache::new(hd, cfg.rope_theta);
        rope.ensure(t);

        let mut slab = KvCache::new(max_seq, n_kv, hd);
        let mut arena = KvArena::new(1, max_seq, n_kv, hd, 4);
        let seq = arena.alloc_seq();
        // uneven appends so arena page claims land mid-block
        let mut fed = 0usize;
        for chunk in [50usize, 31, 64, 64] {
            let n = chunk.min(t - fed);
            if n == 0 {
                break;
            }
            let lo = fed * w;
            append_kv_block(&mut slab, &rope,
                            &k_block[lo..(fed + n) * w],
                            &v_block[lo..(fed + n) * w], n);
            arena.append_kv_block(seq, 0, &rope,
                                  &k_block[lo..(fed + n) * w],
                                  &v_block[lo..(fed + n) * w], n)
                .unwrap();
            fed += n;
        }
        assert_eq!(fed, t);
        assert_eq!(arena.seq_len(seq), t);

        let mut sc = AttnScratch::new();
        // whole-block prefill shape
        let q = rng.normal_vec(t * d, 1.0);
        let mut out_slab = vec![0f32; t * d];
        attention_block(&cfg, &q, &slab, 0, t, &mut sc, None,
                        &mut out_slab);
        let mut out_arena = vec![0f32; t * d];
        let view = arena.layer(seq, 0);
        attention_block(&cfg, &q, &view, 0, t, &mut sc, None,
                        &mut out_arena);
        assert_eq!(out_slab, out_arena,
                   "T={t}: paged attention diverged from the slab");

        // single-query decode shape at the last position
        let q1 = rng.normal_vec(d, 1.0);
        let mut d_slab = vec![0f32; d];
        attention_block(&cfg, &q1, &slab, t - 1, 1, &mut sc, None,
                        &mut d_slab);
        let mut d_arena = vec![0f32; d];
        let view = arena.layer(seq, 0);
        attention_block(&cfg, &q1, &view, t - 1, 1, &mut sc, None,
                        &mut d_arena);
        assert_eq!(d_slab, d_arena, "T={t}: decode shape diverged");
    }
}

/// Arena-backed `forward_logits` (block prefill) vs per-token
/// `decode_step` right below / at / past page seams.
#[test]
fn arena_forward_parity_at_page_boundaries() {
    let model = synth_model_shaped(7, 4, 2, 160);
    let prec = Precision::Fixed(2);
    for &t in &[KV_PAGE - 1, KV_PAGE, KV_PAGE + 1, 2 * KV_PAGE + 1] {
        let tokens: Vec<u32> = (0..t)
            .map(|i| ((i * 7 + 3) % model.cfg.vocab_size) as u32)
            .collect();
        let block = model.forward_logits(&tokens, prec).unwrap();

        let (mut arena, seq) = model.new_kv();
        let mut scratch = model.new_scratch();
        let mut stats = DecodeStats::new(model.cfg.n_layers);
        let mut per_tok = Vec::new();
        for &tok in &tokens {
            model.decode_step(tok, &mut arena, seq, prec, &mut scratch,
                              &mut stats).unwrap();
            per_tok.extend_from_slice(&scratch.logits);
        }
        assert_eq!(block.len(), per_tok.len());
        for (i, (a, b)) in block.iter().zip(&per_tok).enumerate() {
            assert!((a - b).abs() < TOL,
                    "T={t} logits[{i}]: block {a} vs per-token {b}");
        }
    }
}

/// COW fork mid-page: a fork sharing 100 positions (1.5 pages) and its
/// source, fed the same continuation, must produce bit-identical
/// logits — and both must equal a cold sequence fed the full stream
/// (same kernels, same positions, so exactly equal, not just close).
#[test]
fn cow_fork_mid_page_parity() {
    let model = synth_model_shaped(95, 4, 2, 256);
    let prec = Precision::Fixed(2);
    let mut arena = model.new_arena(4);
    let mut scratch = model.new_scratch();
    let tok = |i: usize| ((i * 5 + 11) % model.cfg.vocab_size) as u32;
    let shared = 100usize; // mid-page: 1 full page + 36 rows
    let cont: Vec<u32> = (0..20).map(|i| tok(1000 + i)).collect();

    let a = arena.alloc_seq();
    let mut sa = DecodeStats::new(model.cfg.n_layers);
    for i in 0..shared {
        model.decode_step(tok(i), &mut arena, a, prec, &mut scratch,
                          &mut sa).unwrap();
    }
    let resident_before = arena.resident_pages();
    let b = arena.fork_prefix(a, shared);
    assert_eq!(arena.resident_pages(), resident_before,
               "fork must not copy pages");
    assert_eq!(arena.seq_len(b), shared);

    // source first (COWs the shared partial page), then the fork
    let mut la = Vec::new();
    for &tk in &cont {
        model.decode_step(tk, &mut arena, a, prec, &mut scratch,
                          &mut sa).unwrap();
        la.extend_from_slice(&scratch.logits);
    }
    let mut sb = DecodeStats::new(model.cfg.n_layers);
    let mut lb = Vec::new();
    for &tk in &cont {
        model.decode_step(tk, &mut arena, b, prec, &mut scratch,
                          &mut sb).unwrap();
        lb.extend_from_slice(&scratch.logits);
    }
    assert_eq!(la, lb, "fork diverged from source after COW");

    // cold recompute of the full stream
    let c = arena.alloc_seq();
    let mut sc = DecodeStats::new(model.cfg.n_layers);
    let mut lc = Vec::new();
    for i in 0..shared {
        model.decode_step(tok(i), &mut arena, c, prec, &mut scratch,
                          &mut sc).unwrap();
    }
    for &tk in &cont {
        model.decode_step(tk, &mut arena, c, prec, &mut scratch,
                          &mut sc).unwrap();
        lc.extend_from_slice(&scratch.logits);
    }
    assert_eq!(la, lc, "shared-page path diverged from cold recompute");

    // lifecycle: freeing all three returns every page
    arena.free_seq(a);
    arena.free_seq(b);
    arena.free_seq(c);
    assert_eq!(arena.resident_pages(), 0);
}

fn mk_req(id: u64, prompt: Vec<u32>, max_new: usize)
          -> (Request, mpsc::Receiver<Response>) {
    mk_req_at(id, prompt, max_new, KvPrecision::F32)
}

fn mk_req_at(id: u64, prompt: Vec<u32>, max_new: usize,
             kv: KvPrecision) -> (Request, mpsc::Receiver<Response>) {
    let (tx, rx) = mpsc::channel();
    (Request {
        id,
        prompt,
        max_new_tokens: max_new,
        kv_precision: kv,
        submitted: Instant::now(),
        reply: tx,
    }, rx)
}

fn fixed_controller() -> ElasticController {
    ElasticController::new(ControllerConfig {
        min_bits: 4.0,
        max_bits: 4.0,
        ..ControllerConfig::default()
    })
}

/// Out-of-pages admission backpressure: with a 3-page budget and
/// 2-page requests, only one sequence runs at a time; the others queue
/// (no panic), retire frees their pages, and everyone completes.
#[test]
fn out_of_pages_queues_and_retire_readmits() {
    let model = synth_model_shaped(93, 4, 2, 128);
    assert_eq!(model.cfg.n_layers, 2);
    let batcher = Batcher::new(4, 16).with_kv_budget(3);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    let mut rxs = Vec::new();
    for id in 0..3u64 {
        // distinct 40-token prompts, 4 new tokens: worst case is
        // 2 layers x 1 page = 2 pages per request
        let prompt: Vec<u32> = (0..40)
            .map(|i| ((i * 3 + 7 * id as usize) % 256) as u32)
            .collect();
        let (req, rx) = mk_req(id, prompt, 4);
        sched.submit(req);
        rxs.push(rx);
    }
    sched.tick(0.0).unwrap();
    assert_eq!(sched.n_active(), 1,
               "page budget must gate admission to one sequence");
    assert_eq!(sched.batcher.queued(), 2);
    assert!(sched.batcher.deferred() > 0,
            "blocked admissions must be counted, not panicked");

    sched.run_to_completion(|_| 0.0).unwrap();
    for rx in rxs {
        let resp = rx.try_recv().expect("every queued request finishes");
        assert_eq!(resp.metrics.generated_tokens, 4);
    }
    assert_eq!(sched.metrics.requests_completed, 3);
    assert!(sched.metrics.admissions_deferred > 0);
    assert!(sched.arena.peak_resident_pages() <= 3,
            "budget must bound peak residency");
    assert_eq!(sched.arena.resident_pages(), 0,
               "retire must return all pages (no prefix cache here: \
                prompts are shorter than one page)");
}

/// Shared-prefix serving: a second identical prompt forks the cached
/// prefix pages instead of recomputing them — same output tokens, one
/// cache hit, one page-aligned prefix worth of prefill skipped.
#[test]
fn prefix_sharing_matches_cold_run() {
    let model = synth_model_shaped(91, 4, 2, 256);
    let batcher = Batcher::new(2, 16);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    let prompt: Vec<u32> = (0..80)
        .map(|i| ((i * 7 + 3) % 256) as u32)
        .collect();

    let (r1, rx1) = mk_req(0, prompt.clone(), 6);
    sched.submit(r1);
    sched.run_to_completion(|_| 0.0).unwrap();
    let cold = rx1.try_recv().expect("cold response");
    assert_eq!(sched.metrics.prefix_hits, 0);
    assert_eq!(sched.metrics.prefix_misses, 1);

    let (r2, rx2) = mk_req(1, prompt.clone(), 6);
    sched.submit(r2);
    sched.run_to_completion(|_| 0.0).unwrap();
    let warm = rx2.try_recv().expect("warm response");

    assert_eq!(warm.tokens, cold.tokens,
               "shared-prefix decode must match the cold run exactly");
    assert_eq!(sched.metrics.prefix_hits, 1);
    // 80-token prompt -> one full page (64) is shareable
    assert_eq!(sched.metrics.prefix_tokens_reused, KV_PAGE as u64);
    assert!(sched.metrics.prefix_hit_rate() > 0.49);
}

// ---------------------------------------------------------------------------
// Quantized KV pages (ISSUE 5)
// ---------------------------------------------------------------------------

/// Relative error of `got` vs the oracle `want`, normalised by the
/// oracle's largest magnitude (guarded for all-zero oracles).
fn rel_err(got: &[f32], want: &[f32]) -> f32 {
    let mut max_err = 0f32;
    let mut max_abs = 0f32;
    for (a, b) in got.iter().zip(want) {
        max_err = max_err.max((a - b).abs());
        max_abs = max_abs.max(b.abs());
    }
    max_err / max_abs.max(1e-6)
}

/// Append the same random K/V stream (uneven chunks crossing page
/// seams) to a slab and to an arena sequence at `kvp`; returns both.
fn paired_fill(cfg: &ModelConfig, t: usize, seed: u64,
               kvp: KvPrecision) -> (KvCache, KvArena,
                                     mobiquant::model::KvHandle) {
    let hd = cfg.head_dim();
    let n_kv = cfg.n_kv_heads;
    let w = n_kv * hd;
    let mut rng = Pcg::new(seed);
    let k_block = rng.normal_vec(t * w, 1.0);
    let v_block = rng.normal_vec(t * w, 1.0);
    let mut rope = RopeCache::new(hd, cfg.rope_theta);
    rope.ensure(t);

    let mut slab = KvCache::new(cfg.max_seq_len, n_kv, hd);
    let mut arena = KvArena::new(1, cfg.max_seq_len, n_kv, hd, 8);
    let seq = arena.alloc_seq_at(kvp);
    let mut fed = 0usize;
    for chunk in [50usize, 31, 64, 64] {
        let n = chunk.min(t - fed);
        if n == 0 {
            break;
        }
        let lo = fed * w;
        append_kv_block(&mut slab, &rope, &k_block[lo..(fed + n) * w],
                        &v_block[lo..(fed + n) * w], n);
        arena.append_kv_block(seq, 0, &rope,
                              &k_block[lo..(fed + n) * w],
                              &v_block[lo..(fed + n) * w], n)
            .unwrap();
        fed += n;
    }
    assert_eq!(fed, t);
    (slab, arena, seq)
}

/// Quantized append -> tile-read round-trip at page seams: every
/// dequantized element stays within 1.5 absmax steps of the exact slab
/// row — the bound the `SCALE_GROW` widening hysteresis guarantees no
/// matter how many times the page's range grew.
#[test]
fn quantized_roundtrip_error_bound_at_page_seams() {
    let cfg = attn_cfg(4, 2, 16, 3 * KV_PAGE);
    for &kvp in &[KvPrecision::Int8, KvPrecision::Int4] {
        for &t in &[63usize, 64, 65, 129] {
            let (slab, arena, seq) =
                paired_fill(&cfg, t, 500 + t as u64, kvp);
            let view = arena.layer(seq, 0);
            for head in 0..cfg.n_kv_heads {
                let mut p = 0usize;
                while p < t {
                    let end = (p + KV_PAGE).min(t);
                    for side_k in [true, false] {
                        let (run, exact) = if side_k {
                            (view.k_run(head, p, end),
                             slab.k_run(head, p, end).as_f32().unwrap())
                        } else {
                            (view.v_run(head, p, end),
                             slab.v_run(head, p, end).as_f32().unwrap())
                        };
                        let deq = run.dequant(cfg.head_dim());
                        let tol = 1.5 * run.scale();
                        for (i, (a, b)) in
                            deq.iter().zip(exact).enumerate() {
                            assert!((a - b).abs() <= tol,
                                    "{} T={t} head {head} run [{p}, \
                                     {end}) elem {i}: {a} vs {b} \
                                     (tol {tol})", kvp.label());
                        }
                    }
                    p = end;
                }
            }
        }
    }
}

/// Quantized paged attention vs the f32 slab oracle across GQA shapes
/// (incl. n_kv < n_heads), prefill + decode shapes and page-seam
/// lengths: i8 within 1e-2 relative error, u4 within 0.3.  The f32
/// paged path stays bit-identical (pinned above by
/// `arena_attention_bit_identical_to_slab_oracle`).
#[test]
fn quantized_attention_tracks_slab_oracle() {
    for &(n_heads, n_kv) in &[(4usize, 2usize), (4, 4), (8, 2)] {
        let cfg = attn_cfg(n_heads, n_kv, 16, 3 * KV_PAGE);
        let d = cfg.d_model;
        for &t in &[63usize, 64, 65, 129] {
            let mut rng = Pcg::new(700 + t as u64 + n_heads as u64);
            let q = rng.normal_vec(t * d, 1.0);
            let q1 = rng.normal_vec(d, 1.0);

            // oracle: the same kernel over the exact f32 slab
            let (slab, _, _) =
                paired_fill(&cfg, t, 600 + t as u64, KvPrecision::F32);
            let mut sc = AttnScratch::new();
            let mut want = vec![0f32; t * d];
            attention_block(&cfg, &q, &slab, 0, t, &mut sc, None,
                            &mut want);
            let mut want1 = vec![0f32; d];
            attention_block(&cfg, &q1, &slab, t - 1, 1, &mut sc, None,
                            &mut want1);

            for &(kvp, tol) in &[(KvPrecision::Int8, 1e-2f32),
                                 (KvPrecision::Int4, 0.3)] {
                let (_, arena, seq) =
                    paired_fill(&cfg, t, 600 + t as u64, kvp);
                let view = arena.layer(seq, 0);
                // whole-block prefill shape
                let mut got = vec![0f32; t * d];
                attention_block(&cfg, &q, &view, 0, t, &mut sc, None,
                                &mut got);
                let e = rel_err(&got, &want);
                assert!(e <= tol,
                        "{} {n_heads}h/{n_kv}kv T={t} prefill rel err \
                         {e} > {tol}", kvp.label());
                // single-query decode shape at the last position
                let mut got1 = vec![0f32; d];
                attention_block(&cfg, &q1, &view, t - 1, 1, &mut sc,
                                None, &mut got1);
                let e1 = rel_err(&got1, &want1);
                assert!(e1 <= tol,
                        "{} {n_heads}h/{n_kv}kv T={t} decode rel err \
                         {e1} > {tol}", kvp.label());
            }
        }
    }
}

/// COW on a shared quantized partial page: the fork's append (with an
/// absmax spike that forces a re-code on its copy) must leave the
/// source's bytes AND scales untouched.
#[test]
fn quantized_cow_preserves_source_scales_and_bytes() {
    let (n_kv, hd) = (2usize, 4usize);
    let max_seq = 4 * KV_PAGE;
    let t0 = KV_PAGE + KV_PAGE / 2; // one full + one partial page
    let w = n_kv * hd;
    let mut rng = Pcg::new(41);
    let k_block = rng.normal_vec(t0 * w, 1.0);
    let v_block = rng.normal_vec(t0 * w, 1.0);
    let mut rope = RopeCache::new(hd, 1e4);
    rope.ensure(max_seq);

    let mut arena = KvArena::new(1, max_seq, n_kv, hd, 8);
    let src = arena.alloc_seq_at(KvPrecision::Int8);
    arena.append_kv_block(src, 0, &rope, &k_block, &v_block, t0)
        .unwrap();
    let resident = arena.resident_pages();
    assert_eq!(resident, 2);

    // snapshot the source's dequantized rows and scales
    let snap_k: Vec<Vec<f32>> = (0..n_kv)
        .map(|h| arena.layer(src, 0).k_run(h, KV_PAGE, t0).dequant(hd))
        .collect();
    let snap_scale: Vec<f32> = (0..n_kv)
        .map(|h| arena.layer(src, 0).k_run(h, KV_PAGE, t0).scale())
        .collect();

    let fork = arena.fork_prefix(src, t0);
    assert_eq!(arena.resident_pages(), resident,
               "fork must not copy pages");
    // a huge appended row forces the fork's COW'd page to re-code
    let spike_k = vec![50.0f32; w];
    let spike_v = vec![-50.0f32; w];
    arena.append_kv_block(fork, 0, &rope, &spike_k, &spike_v, 1)
        .unwrap();
    assert_eq!(arena.resident_pages(), resident + 1,
               "COW copies exactly one page");

    for h in 0..n_kv {
        let run = arena.layer(src, 0).k_run(h, KV_PAGE, t0);
        assert_eq!(run.scale(), snap_scale[h],
                   "head {h}: source scale changed by the fork's COW");
        assert_eq!(run.dequant(hd), snap_k[h],
                   "head {h}: source bytes changed by the fork's COW");
        // the fork's copy now holds a wider scale than the source
        let frun = arena.layer(fork, 0).k_run(h, KV_PAGE, t0 + 1);
        assert!(frun.scale() > snap_scale[h],
                "head {h}: fork page must have re-coded to the spike");
    }
}

/// Mixed-precision arenas end-to-end: an f32 sequence and an i8
/// sequence decoding side by side in one arena — the f32 sequence's
/// logits must be bit-identical to an f32-only run (no slab aliasing,
/// no cross-pool interference), and per-pool residency adds up.
#[test]
fn mixed_precision_arena_forward_isolation() {
    let model = synth_model_shaped(97, 4, 2, 256);
    let prec = Precision::Fixed(2);
    let toks: Vec<u32> = (0..80)
        .map(|i| ((i * 11 + 5) % 256) as u32)
        .collect();

    // f32-only baseline
    let (mut arena_a, seq_a) = model.new_kv();
    let mut scratch = model.new_scratch();
    let mut sa = DecodeStats::new(model.cfg.n_layers);
    let mut base = Vec::new();
    for &tk in &toks {
        model.decode_step(tk, &mut arena_a, seq_a, prec, &mut scratch,
                          &mut sa).unwrap();
        base.extend_from_slice(&scratch.logits);
    }

    // mixed arena: interleave an f32 and an i8 sequence
    let mut arena = model.new_arena(2);
    let f = arena.alloc_seq_at(KvPrecision::F32);
    let q = arena.alloc_seq_at(KvPrecision::Int8);
    let mut sf = DecodeStats::new(model.cfg.n_layers);
    let mut sq = DecodeStats::new(model.cfg.n_layers);
    let mut mixed = Vec::new();
    for &tk in &toks {
        model.decode_step(tk, &mut arena, q, prec, &mut scratch,
                          &mut sq).unwrap();
        model.decode_step(tk, &mut arena, f, prec, &mut scratch,
                          &mut sf).unwrap();
        mixed.extend_from_slice(&scratch.logits);
    }
    assert_eq!(mixed, base,
               "an i8 neighbour must not perturb f32 decode at all");
    assert_eq!(arena.resident_pages_at(KvPrecision::F32),
               model.cfg.n_layers * (80usize.div_ceil(KV_PAGE)));
    assert_eq!(arena.resident_pages_at(KvPrecision::Int8),
               model.cfg.n_layers * (80usize.div_ceil(KV_PAGE)));
    assert_eq!(arena.resident_bytes(),
               arena.resident_pages_at(KvPrecision::F32)
                   * arena.page_bytes()
               + arena.resident_pages_at(KvPrecision::Int8)
                   * arena.page_bytes_at(KvPrecision::Int8));
}

/// Regression (ISSUE 5 satellite): the prefix-cache key includes the
/// KV storage precision — a cached f32-page prefix must never be
/// forked into an i8 sequence (and an i8 prefix must hit a later i8
/// request).
#[test]
fn prefix_cache_keys_on_kv_precision() {
    let model = synth_model_shaped(91, 4, 2, 256);
    let batcher = Batcher::new(2, 16);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    let prompt: Vec<u32> = (0..80)
        .map(|i| ((i * 7 + 3) % 256) as u32)
        .collect();

    // 1: f32 run registers an f32 prefix
    let (r1, rx1) = mk_req(0, prompt.clone(), 6);
    sched.submit(r1);
    sched.run_to_completion(|_| 0.0).unwrap();
    rx1.try_recv().expect("f32 response");
    assert_eq!(sched.metrics.prefix_misses, 1);

    // 2: identical prompt at i8 must MISS (different storage bytes,
    // different pool) and register its own i8 entry
    let (r2, rx2) = mk_req_at(1, prompt.clone(), 6, KvPrecision::Int8);
    sched.submit(r2);
    sched.run_to_completion(|_| 0.0).unwrap();
    rx2.try_recv().expect("i8 response");
    assert_eq!(sched.metrics.prefix_hits, 0,
               "an f32 prefix must never serve an i8 request");
    assert_eq!(sched.metrics.prefix_misses, 2);

    // 3: a second i8 request now hits the i8 entry...
    let (r3, rx3) = mk_req_at(2, prompt.clone(), 6, KvPrecision::Int8);
    sched.submit(r3);
    sched.run_to_completion(|_| 0.0).unwrap();
    let warm = rx3.try_recv().expect("warm i8 response");
    assert_eq!(sched.metrics.prefix_hits, 1);
    assert_eq!(sched.metrics.prefix_tokens_reused, KV_PAGE as u64);

    // ...and a third f32 request still hits the f32 entry
    let (r4, rx4) = mk_req(3, prompt.clone(), 6);
    sched.submit(r4);
    sched.run_to_completion(|_| 0.0).unwrap();
    let warm_f32 = rx4.try_recv().expect("warm f32 response");
    assert_eq!(sched.metrics.prefix_hits, 2);
    // same-precision shared pages reproduce the cold outputs exactly
    assert_eq!(warm_f32.tokens.len(), warm.tokens.len());
}

/// Byte-accurate admission: under the same page budget, i8 requests
/// admit 4x the slots of f32 requests (the scheduler's reservation is
/// in bytes at the request's storage precision).
#[test]
fn i8_admits_4x_slots_under_equal_budget() {
    let model = synth_model_shaped(93, 4, 2, 128);
    let prompt_of = |id: u64| -> Vec<u32> {
        (0..40).map(|i| ((i * 3 + 7 * id as usize) % 256) as u32)
            .collect()
    };
    // worst case per request: 2 layers x 1 page = 2 f32 pages
    let mut admitted = Vec::new();
    for &kvp in &[KvPrecision::F32, KvPrecision::Int8] {
        let batcher = Batcher::new(16, 32).with_kv_budget(4);
        let mut sched = Scheduler::new(&model, batcher,
                                       fixed_controller());
        let mut rxs = Vec::new();
        for id in 0..12u64 {
            let (req, rx) = mk_req_at(id, prompt_of(id), 4, kvp);
            sched.submit(req);
            rxs.push(rx);
        }
        sched.tick(0.0).unwrap();
        admitted.push(sched.n_active());
        // everyone still completes eventually
        sched.run_to_completion(|_| 0.0).unwrap();
        for rx in rxs {
            rx.try_recv().expect("queued request must finish");
        }
        assert_eq!(sched.arena.resident_bytes(), 0,
                   "retire must return all bytes");
    }
    assert_eq!(admitted[0], 2, "f32: 4-page budget / 2 pages each");
    assert_eq!(admitted[1], 8, "i8 must admit 4x the f32 slots");
}
