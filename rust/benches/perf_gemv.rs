//! §Perf — L3 hot-path kernel study (EXPERIMENTS.md §Perf).
//!
//! Compares the GEMV kernel variants at production-like dims and reports
//! effective bit-plane bandwidth and speedups:
//!   dense f32 matvec (roofline comparator, loads 16x the bytes of a
//!   2-bit plane pass), naive bit-iteration, byte-LUT bit-serial (the
//!   shipped kernel), and the slice-traffic proportionality.

use mobiquant::mobiq::bitplane::PackedSlice;
use mobiquant::mobiq::gemv::{gemv_bitserial, gemv_lut, gemv_lut_simple,
                             matvec, TokenLut};
use mobiquant::mobiq::quantizer::{decompose, reconstruct, GroupParams};
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;

fn main() {
    let mut suite = Suite::new("perf_gemv");
    suite.header();
    let mut rng = Pcg::new(1);

    for (d_in, d_out) in [(1024usize, 1024usize), (4096, 4096)] {
        let gs = 32;
        let w = rng.normal_vec(d_in * d_out, 0.1);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = decompose(&w, &base, 4);
        let slices: Vec<PackedSlice> = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        let dense = reconstruct(&codes, &base, 2);
        let x = rng.normal_vec(d_in, 1.0);
        let gsums: Vec<f32> = (0..d_in / gs)
            .map(|g| x[g * gs..(g + 1) * gs].iter().sum())
            .collect();
        let mut out = vec![0f32; d_out];
        let mut lut = TokenLut::new(d_in, gs);
        let tag = format!("{d_in}x{d_out}");

        let ns_dense = suite.bench(&format!("{tag} dense f32 (4B/w)"),
            || {
                matvec(&dense, &x, &mut out, d_in, d_out);
                black_box(out[0]);
            });

        let active2 = [true, false, false, false];
        let ns_bits = suite.bench(
            &format!("{tag} bitserial iter @2bit"), || {
                gemv_bitserial(&slices, &base, &x, &gsums, &active2,
                               &mut out);
                black_box(out[0]);
            });
        // v1 reads the byte table, which build() skips above the nibble
        // threshold — only compare below it.
        let ns_lut_v1 = if d_in >= 2048 {
            f64::NAN
        } else {
            suite.bench(
                &format!("{tag} LUT-v1 (per-group calls) @2bit"), || {
                    lut.build(&x, gs);
                    gemv_lut_simple(&slices, &base, &lut, &active2,
                                    &mut out);
                    black_box(out[0]);
                })
        };
        let ns_lut2 = suite.bench(&format!("{tag} LUT @2bit"), || {
            lut.build(&x, gs);
            gemv_lut(&slices, &base, &lut, &active2, &mut out);
            black_box(out[0]);
        });
        let active8 = [true, true, true, true];
        let ns_lut8 = suite.bench(&format!("{tag} LUT @8bit"), || {
            lut.build(&x, gs);
            gemv_lut(&slices, &base, &lut, &active8, &mut out);
            black_box(out[0]);
        });

        let plane_bytes_2b = slices[0].nbytes() as f64;
        suite.row(&format!("{tag} summary"), &[
            ("lut_speedup_vs_v1", ns_lut_v1 / ns_lut2),
            ("lut_speedup_vs_bitserial", ns_bits / ns_lut2),
            ("lut2b_speedup_vs_dense", ns_dense / ns_lut2),
            ("traffic_ratio_2b_vs_dense",
             plane_bytes_2b / (d_in * d_out * 4) as f64),
            ("plane_GBps_2b", plane_bytes_2b / ns_lut2),
            ("lut8b_over_lut2b", ns_lut8 / ns_lut2),
        ]);
    }
    suite.note("targets: LUT >= 3x over bitserial; 2-bit pass faster \
                than dense f32 while moving 16x fewer weight bytes; \
                cost scaling ~linear in active slices");
    suite.finish();
}
