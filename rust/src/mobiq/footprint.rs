//! Memory footprint accounting — Fig. 7 (right) and the §5.2 "3.5x memory
//! savings vs separate multi-precision deployment" claim.
//!
//! Deployment scenarios compared at equal *served precisions*
//! {2, 4, 6, 8}-bit:
//!
//! * `multi_static`  — one statically packed model per precision, each
//!   with its own scales (what a MatQuant/offline-repack deployment
//!   stores).
//! * `anybcq_like`   — single bit-plane model but per-precision scale
//!   sets (AnyBCQ).
//! * `mobiq`         — single bit-plane model, ONE shared scale set, plus
//!   routers and threshold tables.
//! * `fp16`          — the unquantized comparator.

use crate::model::kvcache::KvPrecision;

/// Per-linear dimensions needed for the accounting.
#[derive(Debug, Clone, Copy)]
pub struct LinearDims {
    pub d_in: usize,
    pub d_out: usize,
}

#[derive(Debug, Clone)]
pub struct FootprintInputs {
    pub linears: Vec<LinearDims>,
    pub group_size: usize,
    pub n_slices: usize,
    pub slice_bits: usize,
    pub router_hidden: usize,
    /// Non-quantized residue: embeddings, norms, lm_head (bytes, fp32).
    pub fp_other_bytes: usize,
}

impl FootprintInputs {
    fn weights(&self) -> usize {
        self.linears.iter().map(|l| l.d_in * l.d_out).sum()
    }

    fn scale_entries(&self) -> usize {
        self.linears.iter()
            .map(|l| (l.d_in / self.group_size) * l.d_out)
            .sum()
    }

    pub fn fp16_bytes(&self) -> usize {
        self.weights() * 2 + self.fp_other_bytes
    }

    /// One statically packed model at `bits` (codes + scale/zero f32).
    pub fn static_bytes(&self, bits: usize) -> usize {
        self.weights() * bits / 8 + self.scale_entries() * 8
            + self.fp_other_bytes
    }

    /// Separate deployment of every served precision.
    pub fn multi_static_bytes(&self, precisions: &[usize]) -> usize {
        precisions.iter().map(|&b| self.static_bytes(b)).sum()
    }

    /// AnyBCQ-like: shared bit-planes but per-precision scales.
    pub fn anybcq_bytes(&self, precisions: &[usize]) -> usize {
        self.weights() * (self.n_slices * self.slice_bits) / 8
            + self.scale_entries() * 8 * precisions.len()
            + self.fp_other_bytes
    }

    pub fn router_bytes(&self) -> usize {
        self.linears.iter()
            .map(|l| {
                4 * (l.d_in * self.router_hidden
                    + self.router_hidden * (self.n_slices - 1)
                    + self.router_hidden + (self.n_slices - 1))
                    + 129 * 4 // threshold quantile grid
            })
            .sum()
    }

    /// MoBiQuant: all planes + ONE scale set + routers.
    pub fn mobiq_bytes(&self) -> usize {
        self.weights() * (self.n_slices * self.slice_bits) / 8
            + self.scale_entries() * 8
            + self.router_bytes()
            + self.fp_other_bytes
    }

    /// Headline ratio: multi-precision deployment vs MoBiQuant.
    pub fn savings_vs_multi(&self, precisions: &[usize]) -> f64 {
        self.multi_static_bytes(precisions) as f64
            / self.mobiq_bytes() as f64
    }
}

// ---------------------------------------------------------------------------
// KV memory accounting (paged arena vs eager slabs)
// ---------------------------------------------------------------------------

/// Fig. 7-style serving-side KV accounting: what the eager per-slot
/// slab deployment resident-allocates vs the paged arena
/// (`model::kvcache::KvArena`), including shared-prefix dedup and
/// quantized page storage ([`KvPrecision`]: i8 pages are 4x smaller
/// than f32, bit-packed i4 8x).  The arena reports *measured* resident
/// bytes at runtime (`coordinator::metrics`); this struct is the
/// analytic counterpart used by reports and the `perf_kv` bench.
#[derive(Debug, Clone, Copy)]
pub struct KvFootprint {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq_len: usize,
    /// Positions per page (`model::kvcache::KV_PAGE` at runtime).
    pub kv_page: usize,
}

impl KvFootprint {
    /// Bytes of one KV page (K + V sides, f32).
    pub fn page_bytes(&self) -> usize {
        self.page_bytes_at(KvPrecision::F32)
    }

    /// Bytes of one KV page stored at a given precision (per-page-head
    /// scales are O(pages) side metadata, uncounted — matching the
    /// arena's budget accounting).
    pub fn page_bytes_at(&self, prec: KvPrecision) -> usize {
        2 * self.n_kv_heads * self.kv_page
            * prec.row_bytes(self.head_dim)
    }

    /// What one eager slab slot always allocates: full context for
    /// every layer regardless of actual sequence length.
    pub fn slab_bytes_per_seq(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.max_seq_len
            * self.head_dim * 4
    }

    /// Eager deployment for `n_seqs` concurrent slots.
    pub fn eager_bytes(&self, n_seqs: usize) -> usize {
        n_seqs * self.slab_bytes_per_seq()
    }

    /// Pages one sequence of `len` positions maps per layer.
    pub fn pages_for(&self, len: usize) -> usize {
        (len + self.kv_page - 1) / self.kv_page
    }

    /// Paged-arena resident bytes for independent sequences of the
    /// given lengths (no sharing).
    pub fn paged_bytes(&self, seq_lens: &[usize]) -> usize {
        self.paged_bytes_at(KvPrecision::F32, seq_lens)
    }

    /// Paged-arena resident bytes with every sequence's pages stored
    /// at `prec`.
    pub fn paged_bytes_at(&self, prec: KvPrecision,
                          seq_lens: &[usize]) -> usize {
        seq_lens.iter()
            .map(|&l| self.n_layers * self.pages_for(l)
                 * self.page_bytes_at(prec))
            .sum()
    }

    /// Paged-arena resident bytes when every sequence shares one
    /// `shared_len`-token prompt prefix (stored once) and keeps only
    /// its own tail pages.
    pub fn paged_bytes_shared(&self, shared_len: usize,
                              tail_lens: &[usize]) -> usize {
        let shared = self.n_layers * self.pages_for(shared_len)
            * self.page_bytes();
        let tails: usize = tail_lens.iter()
            .map(|&l| self.n_layers * self.pages_for(l)
                 * self.page_bytes())
            .sum();
        shared + tails
    }

    /// Headline ratio: eager slabs vs paged residency for the given
    /// actual sequence lengths.
    pub fn savings_vs_eager(&self, seq_lens: &[usize]) -> f64 {
        self.eager_bytes(seq_lens.len()) as f64
            / self.paged_bytes(seq_lens).max(1) as f64
    }

    /// Eager f32 slabs vs paged residency at a storage precision —
    /// the paging and quantization savings compose multiplicatively.
    pub fn savings_vs_eager_at(&self, prec: KvPrecision,
                               seq_lens: &[usize]) -> f64 {
        self.eager_bytes(seq_lens.len()) as f64
            / self.paged_bytes_at(prec, seq_lens).max(1) as f64
    }

    /// Steady-state residency ratio of f32 pages over `prec` pages at
    /// equal context — the ISSUE's 4x (i8) / 8x (i4) KV rows.
    pub fn savings_vs_f32_pages(&self, prec: KvPrecision) -> f64 {
        self.page_bytes() as f64 / self.page_bytes_at(prec) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_scale_inputs() -> FootprintInputs {
        // LLaMA-2-7B-like dims to sanity check against the paper's 3.5x
        let d = 4096;
        let f = 11008;
        let per_layer = vec![
            LinearDims { d_in: d, d_out: d },   // q
            LinearDims { d_in: d, d_out: d },   // k
            LinearDims { d_in: d, d_out: d },   // v
            LinearDims { d_in: d, d_out: d },   // o
            LinearDims { d_in: d, d_out: f },   // gate
            LinearDims { d_in: d, d_out: f },   // up
            LinearDims { d_in: f, d_out: d },   // down
        ];
        let linears: Vec<LinearDims> = (0..32)
            .flat_map(|_| per_layer.clone())
            .collect();
        FootprintInputs {
            linears,
            group_size: 128,
            n_slices: 4,
            slice_bits: 2,
            router_hidden: 16,
            fp_other_bytes: 32000 * d * 4 * 2,
        }
    }

    #[test]
    fn savings_in_paper_ballpark() {
        let fi = paper_scale_inputs();
        let s = fi.savings_vs_multi(&[2, 4, 6, 8]);
        // paper reports up to 3.5x; exact value depends on what the
        // multi-deployment duplicates. Require the right order.
        assert!(s > 2.0 && s < 4.0, "savings {s}");
    }

    #[test]
    fn mobiq_smaller_than_fp16() {
        let fi = paper_scale_inputs();
        assert!(fi.mobiq_bytes() < fi.fp16_bytes());
    }

    #[test]
    fn anybcq_larger_than_mobiq() {
        let fi = paper_scale_inputs();
        assert!(fi.anybcq_bytes(&[2, 4, 6, 8]) > fi.mobiq_bytes());
    }

    #[test]
    fn router_overhead_small() {
        let fi = paper_scale_inputs();
        let frac = fi.router_bytes() as f64 / fi.mobiq_bytes() as f64;
        assert!(frac < 0.05, "router overhead {frac}");
    }

    fn kv_fp() -> KvFootprint {
        KvFootprint {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 16,
            max_seq_len: 512,
            kv_page: 64,
        }
    }

    #[test]
    fn paged_short_sequences_beat_eager_4x() {
        // the ISSUE acceptance shape: 32 short sequences, each well
        // under one-quarter of max context
        let fp = kv_fp();
        let lens = [64usize; 32]; // one page per layer each
        let s = fp.savings_vs_eager(&lens);
        assert!(s >= 4.0, "paged savings {s} < 4x for short seqs");
        // and exact: 512/64 = 8x fewer pages than full-context slabs
        assert!((s - 8.0).abs() < 1e-9, "expected exactly 8x, got {s}");
    }

    #[test]
    fn paged_full_context_matches_eager() {
        // at full context the arena pays the same bytes as the slab
        let fp = kv_fp();
        let lens = [fp.max_seq_len; 4];
        assert_eq!(fp.paged_bytes(&lens), fp.eager_bytes(4));
    }

    #[test]
    fn quantized_page_ratios_exact() {
        // the ISSUE's 4x/8x KV rows: i8 pages are exactly a quarter of
        // f32 pages, bit-packed i4 exactly an eighth
        let fp = kv_fp();
        assert_eq!(fp.page_bytes_at(KvPrecision::Int8) * 4,
                   fp.page_bytes());
        assert_eq!(fp.page_bytes_at(KvPrecision::Int4) * 8,
                   fp.page_bytes());
        assert_eq!(fp.savings_vs_f32_pages(KvPrecision::Int8), 4.0);
        assert_eq!(fp.savings_vs_f32_pages(KvPrecision::Int4), 8.0);
    }

    #[test]
    fn paging_and_quantization_compose() {
        // short sequences: 8x from paging (512/64) times 4x (i8) or
        // 8x (i4) from storage — Fig. 7 parity for the serving side
        let fp = kv_fp();
        let lens = [64usize; 32];
        let s8 = fp.savings_vs_eager_at(KvPrecision::Int8, &lens);
        assert!((s8 - 32.0).abs() < 1e-9, "i8 savings {s8}");
        let s4 = fp.savings_vs_eager_at(KvPrecision::Int4, &lens);
        assert!((s4 - 64.0).abs() < 1e-9, "i4 savings {s4}");
        // f32 variant delegates to the original path
        assert_eq!(fp.paged_bytes_at(KvPrecision::F32, &lens),
                   fp.paged_bytes(&lens));
    }

    #[test]
    fn shared_prefix_stores_once() {
        let fp = kv_fp();
        // 8 sequences share a 256-token prompt, 64-token tails each
        let unshared = fp.paged_bytes(&[320usize; 8]);
        let shared = fp.paged_bytes_shared(256, &[64usize; 8]);
        assert!(shared < unshared);
        // 8x(4+1) pages/layer vs (4 + 8x1)
        assert_eq!(unshared / fp.page_bytes() / fp.n_layers, 40);
        assert_eq!(shared / fp.page_bytes() / fp.n_layers, 12);
    }
}
