//! MoBiQuant CLI — leader entrypoint.
//!
//! Subcommands:
//!   info      — bundle inventory + memory footprint report
//!   eval      — perplexity of a backend at a precision
//!   generate  — greedy continuation of a prompt
//!   serve     — drive the elastic server over a synthetic request trace
//!   pjrt      — smoke the PJRT runtime against an AOT HLO module

// Same style-lint stance as lib.rs (CI runs clippy with -D warnings).
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use std::sync::Arc;

use anyhow::{Context, Result};
use mobiquant::coordinator::{Server, ServerConfig};
use mobiquant::data::{corpus, ppl, tokenizer, workload};
use mobiquant::mobiq::artifact::Bundle;
use mobiquant::mobiq::engine::Precision;
use mobiquant::mobiq::footprint::{FootprintInputs, LinearDims};
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::weights::{BackendKind, ModelConfig, LINEAR_NAMES};
use mobiquant::model::Model;
use mobiquant::util::cli::Args;
use mobiquant::util::threadpool::{default_threads, ThreadPool};

fn main() {
    let args = Args::from_env(&["help", "verbose"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "info" => cmd_info(&args),
        "eval" => cmd_eval(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "pjrt" => cmd_pjrt(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mobiquant — token-adaptive any-precision LLM serving\n\
         \n\
         USAGE: mobiquant <cmd> [--model tiny-m] [options]\n\
         \n\
         COMMANDS\n\
         \x20 info                         bundle + footprint report\n\
         \x20 eval      --backend mobiq|fp|<static> --bits B  perplexity\n\
         \x20 generate  --prompt TEXT --tokens N --bits B\n\
         \x20 serve     --requests N --rate R --pressure phased|calm|high\n\
         \x20           --shards N   tensor-parallel worker shards\n\
         \x20                        (default 1; attention heads, FFN\n\
         \x20                        channels and KV pages partition\n\
         \x20                        across N in-process shards — greedy\n\
         \x20                        outputs are bit-identical for every\n\
         \x20                        N; requires N <= n_kv_heads)\n\
         \x20           --host-swap BYTES   host KV swap tier budget\n\
         \x20                        (default 0 = off; under High/\n\
         \x20                        Critical pressure, cold KV pages\n\
         \x20                        move to host memory by exact byte\n\
         \x20                        copy and preemption parks KV there\n\
         \x20                        instead of recomputing it — see\n\
         \x20                        swap_out/swap_in/host_kv_peak in\n\
         \x20                        the metrics summary)\n\
         \x20 pjrt      --variant fp|q2|q4|q6|q8   run AOT module\n\
         \n\
         OPTIONS\n\
         \x20 --threads N   kernel worker threads for eval/generate/serve\n\
         \x20               (default: cores - 1; 1 disables parallelism)\n\
         \x20 --simd MODE   SIMD kernel dispatch for serve: auto (default,\n\
         \x20               detect AVX2/SSE4.1/NEON), on, or off (exact\n\
         \x20               pre-SIMD scalar loops; same as MOBIQ_SIMD)\n"
    );
}

/// Attach the shared kernel worker pool per `--threads` (default:
/// `cores - 1`, see `ThreadPool::default_for_machine`).  A value of 1
/// keeps the serial kernels.
fn attach_pool(model: &mut Model, args: &Args) {
    let n = args.get_usize("threads", default_threads());
    if n > 1 {
        model.set_pool(Arc::new(ThreadPool::new(n)));
    }
}

fn load_bundle(args: &Args) -> Result<(Bundle, String)> {
    let model = args.get_or("model", "tiny-m").to_string();
    let dir = mobiquant::artifacts_dir();
    let path = dir.join(format!("{model}.mobiq"));
    let bundle = Bundle::load(&path)
        .with_context(|| format!("run `make artifacts` first ({path:?})"))?;
    Ok((bundle, model))
}

fn precision_from(args: &Args) -> Precision {
    let bits = args.get_f64("bits", 4.0);
    let delta = args.get_f64("delta", 0.0) as f32;
    Precision::Elastic { target_bits: bits, delta }
}

fn cmd_info(args: &Args) -> Result<()> {
    let (bundle, model) = load_bundle(args)?;
    let cfg = ModelConfig::from_bundle(&bundle)?;
    println!("model {model}: d={} layers={} heads={}/{} ff={} vocab={}",
             cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.n_kv_heads,
             cfg.d_ff, cfg.vocab_size);
    println!("quant: E={} slices x {}b, group={}, router hidden={}",
             cfg.n_slices, cfg.slice_bits, cfg.group_size,
             cfg.router_hidden);
    println!("static methods: {:?}", bundle.static_methods());
    println!("tensors: {}", bundle.names().count());

    let mut linears = Vec::new();
    for _ in 0..cfg.n_layers {
        for name in LINEAR_NAMES {
            let (d_in, d_out) = cfg.linear_dims(name)?;
            linears.push(LinearDims { d_in, d_out });
        }
    }
    let fi = FootprintInputs {
        linears,
        group_size: cfg.group_size,
        n_slices: cfg.n_slices,
        slice_bits: cfg.slice_bits,
        router_hidden: cfg.router_hidden,
        fp_other_bytes: (2 * cfg.vocab_size * cfg.d_model
            + (2 * cfg.n_layers + 1) * cfg.d_model) * 4,
    };
    let served = [2usize, 4, 6, 8];
    println!("\nfootprint (served precisions {served:?}):");
    println!("  fp16:          {:>12} B", fi.fp16_bytes());
    println!("  multi-static:  {:>12} B", fi.multi_static_bytes(&served));
    println!("  anybcq-like:   {:>12} B", fi.anybcq_bytes(&served));
    println!("  mobiquant:     {:>12} B", fi.mobiq_bytes());
    println!("  savings vs multi-static: {:.2}x",
             fi.savings_vs_multi(&served));
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (bundle, model_name) = load_bundle(args)?;
    let backend = args.get_or("backend", "mobiq");
    let kind = match backend {
        "fp" => BackendKind::Fp32,
        "mobiq" => BackendKind::Mobiq,
        other => BackendKind::Static(other.to_string()),
    };
    let mut model = Model::load(&bundle, kind)?;
    attach_pool(&mut model, args);
    let dir = mobiquant::artifacts_dir();
    let domain = args.get_or("domain", "wiki");
    let tokens = corpus::load_tokens(&dir, domain, corpus::Split::Valid)?;
    let precision = precision_from(args);
    let window = args.get_usize("window", 128);
    let maxw = args.get_usize("max-windows", 24);
    let res = ppl::evaluate(&model, &tokens, precision, window, maxw)?;
    println!(
        "{model_name} backend={backend} {:?}: ppl={:.4} avg_bits={:.2} \
         ({} tokens, {domain} valid)",
        precision, res.ppl, res.avg_bits, res.tokens
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let (bundle, _) = load_bundle(args)?;
    let mut model = Model::load(&bundle, BackendKind::Mobiq)?;
    attach_pool(&mut model, args);
    let prompt_text = args.get_or(
        "prompt", "The ancient settlement was founded near ");
    let n = args.get_usize("tokens", 48);
    let precision = precision_from(args);
    let prompt = tokenizer::encode(prompt_text);
    let mut stats = DecodeStats::new(model.cfg.n_layers);
    let t0 = std::time::Instant::now();
    let out = model.generate(&prompt, n, precision, &mut stats)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}", tokenizer::decode(&out));
    println!("\n[{} tokens in {:.2}s = {:.1} tok/s, avg bits {:.2}]",
             out.len(), dt, out.len() as f64 / dt, stats.avg_bits());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (bundle, model_name) = load_bundle(args)?;
    let mut model = Model::load(&bundle, BackendKind::Mobiq)?;
    attach_pool(&mut model, args);
    let dir = mobiquant::artifacts_dir();
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)?;

    let trace_cfg = workload::TraceConfig {
        n_requests: args.get_usize("requests", 12),
        rate_per_s: args.get_f64("rate", 6.0),
        ..Default::default()
    };
    let trace = workload::generate_trace(&toks, &trace_cfg);
    let pressure = match args.get_or("pressure", "phased") {
        "calm" => workload::PressureSignal::constant(0.05),
        "high" => workload::PressureSignal::constant(0.95),
        _ => workload::PressureSignal::phased(4000.0),
    };

    let shards = args.get_usize("shards", 1);
    anyhow::ensure!(shards >= 1 && shards <= model.cfg.n_kv_heads,
                    "--shards must be in 1..={} for this model",
                    model.cfg.n_kv_heads);
    // --simd off pins the byte-identical scalar kernels; on forces the
    // auto-detected wide paths; auto (default) defers to MOBIQ_SIMD.
    let simd = match args.get_or("simd", "auto") {
        "off" | "scalar" | "0" => Some(false),
        "on" | "force" | "1" => Some(true),
        _ => None,
    };
    // --host-swap 0 (the default) keeps the tier off; any positive
    // byte count arms the swap rungs of the pressure ladder.
    let host_swap = args.get_usize("host-swap", 0);
    println!("serving {} requests on {model_name} (elastic precision, \
              {shards} shard{})",
             trace.len(), if shards == 1 { "" } else { "s" });
    let server = Server::start(model, ServerConfig {
        shards,
        simd,
        host_swap_bytes: (host_swap > 0).then_some(host_swap),
        ..ServerConfig::default()
    });
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::new();
    for spec in &trace {
        // pace arrivals
        let now_ms = t0.elapsed().as_secs_f64() * 1000.0;
        if spec.arrival_ms > now_ms {
            std::thread::sleep(std::time::Duration::from_millis(
                (spec.arrival_ms - now_ms) as u64));
        }
        server.set_pressure(
            pressure.at(t0.elapsed().as_secs_f64() * 1000.0));
        receivers.push(
            server.submit(spec.prompt.clone(), spec.max_new_tokens));
    }
    for (id, rx) in receivers {
        let resp = rx.recv()?;
        println!(
            "  req {id}: {} gen tokens, {:.0}ms total ({:.0}ms queue), \
             {:.1} tok/s, avg {:.2} bits",
            resp.metrics.generated_tokens, resp.metrics.total_ms,
            resp.metrics.queue_ms, resp.decode_tokens_per_s(),
            resp.metrics.avg_bits);
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown()?;
    println!("\n{}", metrics.summary(wall));
    Ok(())
}

fn cmd_pjrt(args: &Args) -> Result<()> {
    let (bundle, model_name) = load_bundle(args)?;
    let cfg = ModelConfig::from_bundle(&bundle)?;
    let variant = args.get_or("variant", "fp");
    let dir = mobiquant::artifacts_dir();
    let path = mobiquant::runtime::hlo_path(&dir, &model_name, variant);
    let rt = mobiquant::runtime::PjrtRuntime::cpu()?;
    println!("pjrt platform: {}", rt.platform());
    let module = rt.load(&path)?;
    let tokens = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)?;
    let window = 128;
    let ppl = mobiquant::runtime::ppl_via_pjrt(
        &module, &tokens, window, cfg.vocab_size,
        args.get_usize("max-windows", 8))?;
    println!("{model_name} {variant} via PJRT: ppl={ppl:.4}");
    println!("(cross-check with `mobiquant eval --backend fp`)");
    Ok(())
}
