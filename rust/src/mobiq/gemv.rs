//! MoBiQuant GEMV kernels — the L3 hot path (§4.3 rethought for CPU).
//!
//! The paper's A100 kernel does BMMA directly on bit-planes with a single
//! shared scale and shift-add across slices.  The CPU analogue:
//!
//! * **LUT bit-serial dot** (`gemv_lut`): per token, build 256-entry
//!   masked-sum tables over every 8-activation chunk (cost 32·d_in adds,
//!   amortised over all output channels, planes and slices); a plane's
//!   masked sum is then 1 table lookup per byte of plane words — the
//!   CPU equivalent of bit-plane BMMA.
//! * **Shift-add shared scale**: residual slices accumulate with weights
//!   4^-e into a single per-group partial, multiplied by the *one* stored
//!   scale s1 (paper Fig. 3c).  AnyBCQ's per-slice scales cost an extra
//!   multiply per slice (see baselines::abcq_sim).
//! * **On-demand plane fetch**: inactive slices are never touched, so
//!   memory traffic is proportional to the token's routed precision.
//!
//! `gemv_bitserial` (bit-iteration) and `dequant_gemv` (dense f32) are the
//! perf baseline and the correctness oracle, respectively.

use super::bitplane::PackedSlice;
use super::quantizer::{dequantize, GroupParams};
use crate::util::simd;
use crate::util::threadpool::{SharedMut, ThreadPool};
use crate::util::tunable::TunableGate;

/// Raw output pointer so workers (and the batched kernel's per-token
/// writebacks, and the tensor-parallel shard lanes) can write disjoint
/// cells of one output buffer.  Soundness argument at each use site:
/// every worker/group/shard owns a disjoint (token, o) index set.
pub type SharedOut = SharedMut<f32>;

/// Per-token scratch: byte-chunk LUTs + group sums.  Reused across calls
/// to keep the decode loop allocation-free.
pub struct TokenLut {
    /// (n_chunks, 256) masked partial sums of x over 8-wide chunks.
    pub table: Vec<f32>,
    /// (n_chunks*2, 16) masked sums over 4-wide chunks — 16x smaller,
    /// stays cache-resident at large d_in (see EXPERIMENTS.md §Perf).
    pub ntable: Vec<f32>,
    /// Per-group sums of x (n_groups).
    pub group_sums: Vec<f32>,
    /// Chunks/groups of the activation most recently built (layers with
    /// different d_in share one capacity-sized scratch).
    pub n_chunks: usize,
    pub d_in: usize,
    /// Which table the last build() filled.
    pub nibble: bool,
}

/// d_in at which the byte table (256 entries/chunk) stops fitting cache
/// and the nibble table wins; tuned in the §Perf pass.
const NIBBLE_THRESHOLD: usize = 2048;

impl TokenLut {
    /// `d_in` here is the *capacity*: the largest activation width any
    /// linear will build into this scratch.  The table is padded to a
    /// whole u64 word of chunks so the streaming kernel can read the
    /// padding (always zero) without branching.
    pub fn new(d_in: usize, group_size: usize) -> TokenLut {
        assert_eq!(d_in % 8, 0);
        let padded_chunks = (d_in + 63) / 64 * 8;
        TokenLut {
            table: vec![0f32; padded_chunks * 256],
            ntable: vec![0f32; padded_chunks * 2 * 16],
            group_sums: vec![0f32; (d_in + group_size - 1) / group_size],
            n_chunks: d_in / 8,
            d_in,
            nibble: false,
        }
    }

    /// Build tables for one token's activations (x.len() <= capacity).
    /// Group sums are accumulated inside the chunk loop — the full-mask
    /// entry of each chunk (t[255] / t[15]) is that chunk's total, so the
    /// activation is read exactly once per build instead of a second
    /// scalar-sum pass per group.
    pub fn build(&mut self, x: &[f32], group_size: usize) {
        let padded = (x.len() + 63) / 64 * 8;
        assert!(x.len() % 8 == 0 && padded * 256 <= self.table.len(),
                "activation len {} exceeds LUT capacity", x.len());
        self.d_in = x.len();
        self.n_chunks = x.len() / 8;
        let n_groups = x.len() / group_size;
        self.group_sums[..n_groups].fill(0.0);
        // zero the padding chunks (may hold a previous, wider build)
        self.nibble = x.len() >= NIBBLE_THRESHOLD;
        if self.nibble {
            self.ntable[self.n_chunks * 32..padded * 32].fill(0.0);
            for c in 0..self.n_chunks * 2 {
                let t = &mut self.ntable[c * 16..(c + 1) * 16];
                let xs = &x[c * 4..c * 4 + 4];
                t[0] = 0.0;
                for b in 1usize..16 {
                    t[b] = t[b & (b - 1)]
                        + xs[b.trailing_zeros() as usize];
                }
                // t[15] = the 4-wide chunk total
                let g = c * 4 / group_size;
                if g < n_groups {
                    self.group_sums[g] += t[15];
                }
            }
        } else {
            self.table[self.n_chunks * 256..padded * 256].fill(0.0);
            for c in 0..self.n_chunks {
                let t = &mut self.table[c * 256..(c + 1) * 256];
                let xs = &x[c * 8..c * 8 + 8];
                t[0] = 0.0;
                for b in 1usize..256 {
                    // dynamic programming: drop lowest set bit
                    t[b] = t[b & (b - 1)]
                        + xs[b.trailing_zeros() as usize];
                }
                // t[255] = the 8-wide chunk total
                let g = c * 8 / group_size;
                if g < n_groups {
                    self.group_sums[g] += t[255];
                }
            }
        }
    }

    /// Masked sum of x over the set bits of `plane` (words along d_in),
    /// restricted to group g (group_size must divide 8·words cleanly).
    #[inline]
    fn plane_group_sum(&self, plane: &[u64], g: usize, group_size: usize)
                       -> f32 {
        let c0 = g * group_size / 8;
        let c1 = (g + 1) * group_size / 8;
        let mut acc = 0f32;
        for c in c0..c1 {
            let byte = (plane[c / 8] >> ((c % 8) * 8)) & 0xFF;
            acc += self.table[c * 256 + byte as usize];
        }
        acc
    }
}

/// Residual shift-add weight for slice e: 2^{-bits·e} (shared-scale form).
/// Checked shift: `bits·e >= 64` would overflow the u64 shift (a panic
/// in debug, UB-adjacent wrap in release); such slices weigh less than
/// 2^-64 — below f32 relevance for any accumulated dot — so they
/// resolve to a hard 0.0.
#[inline]
fn slice_weight(e: usize, bits: u32) -> f32 {
    let sh = bits as usize * e;
    if sh >= 64 {
        return 0.0;
    }
    1.0 / (1u64 << sh) as f32
}

/// Scalar walk of one plane's words against the byte LUT — the
/// byte-identical pre-SIMD inner loop (the `MOBIQ_SIMD=off` arm).
/// Accumulates two group partials per word into `ga[w*2..w*2+2]`.
#[inline]
fn byte_words_scalar(plane: &[u64], n_words: usize, table: &[f32],
                     mult: f32, ga: &mut [f32]) {
    for (w, &pw) in plane.iter().enumerate().take(n_words) {
        if pw == 0 {
            continue; // zero word: all LUT hits are 0
        }
        let c0 = w * 8 * 256;
        // SAFETY: table is padded to whole words; byte
        // offsets < 256 by construction.
        unsafe {
            let q0 = *table.get_unchecked(
                c0 + (pw & 0xFF) as usize)
                + *table.get_unchecked(
                    c0 + 256 + ((pw >> 8) & 0xFF) as usize);
            let q1 = *table.get_unchecked(
                c0 + 512 + ((pw >> 16) & 0xFF) as usize)
                + *table.get_unchecked(
                    c0 + 768 + ((pw >> 24) & 0xFF) as usize);
            let q2 = *table.get_unchecked(
                c0 + 1024 + ((pw >> 32) & 0xFF) as usize)
                + *table.get_unchecked(
                    c0 + 1280 + ((pw >> 40) & 0xFF) as usize);
            let q3 = *table.get_unchecked(
                c0 + 1536 + ((pw >> 48) & 0xFF) as usize)
                + *table.get_unchecked(
                    c0 + 1792 + ((pw >> 56) & 0xFF) as usize);
            let g0 = ga.get_unchecked_mut(w * 2);
            *g0 += mult * (q0 + q1);
            let g1 = ga.get_unchecked_mut(w * 2 + 1);
            *g1 += mult * (q2 + q3);
        }
    }
}

/// AVX2-gathered variant of [`byte_words_scalar`]: one `vgatherdps`
/// resolves all 8 bytes of the word, reduced in the identical pairwise
/// tree — bit-identical to the scalar walk (pinned in `util::simd`).
#[inline]
fn byte_words_gather(plane: &[u64], n_words: usize, table: &[f32],
                     mult: f32, ga: &mut [f32]) {
    for (w, &pw) in plane.iter().enumerate().take(n_words) {
        if pw == 0 {
            continue;
        }
        let c0 = w * 8 * 256;
        // SAFETY: the caller hoisted `lut_gather_active()` (AVX2
        // detected), and the table is padded to whole words so
        // c0 + 2048 <= table.len().
        let (h0, h1) = unsafe { simd::lut_bytes_pair(table, c0, pw) };
        ga[w * 2] += mult * h0;
        ga[w * 2 + 1] += mult * h1;
    }
}

/// Scalar walk of one plane's words against the nibble LUT — the
/// byte-identical pre-SIMD inner loop (the `MOBIQ_SIMD=off` arm).
#[inline]
fn nibble_words_scalar(plane: &[u64], n_words: usize, nt: &[f32],
                       mult: f32, ga: &mut [f32]) {
    for (w, &pw) in plane.iter().enumerate().take(n_words) {
        if pw == 0 {
            continue;
        }
        let c0 = w * 16 * 16;
        // SAFETY: ntable padded to whole words;
        // nibble < 16 by construction.
        unsafe {
            let mut q0 = 0f32;
            let mut q1 = 0f32;
            let mut q2 = 0f32;
            let mut q3 = 0f32;
            for j in 0..4 {
                q0 += *nt.get_unchecked(
                    c0 + j * 16
                        + ((pw >> (4 * j)) & 0xF) as usize);
                q1 += *nt.get_unchecked(
                    c0 + (4 + j) * 16
                        + ((pw >> (16 + 4 * j)) & 0xF)
                        as usize);
                q2 += *nt.get_unchecked(
                    c0 + (8 + j) * 16
                        + ((pw >> (32 + 4 * j)) & 0xF)
                        as usize);
                q3 += *nt.get_unchecked(
                    c0 + (12 + j) * 16
                        + ((pw >> (48 + 4 * j)) & 0xF)
                        as usize);
            }
            *ga.get_unchecked_mut(w * 2) +=
                mult * (q0 + q1);
            *ga.get_unchecked_mut(w * 2 + 1) +=
                mult * (q2 + q3);
        }
    }
}

/// AVX2-gathered variant of [`nibble_words_scalar`]: two gathers
/// resolve the 16 nibbles, reduced with the scalar walk's exact
/// left-associated per-group order — bit-identical.
#[inline]
fn nibble_words_gather(plane: &[u64], n_words: usize, nt: &[f32],
                       mult: f32, ga: &mut [f32]) {
    for (w, &pw) in plane.iter().enumerate().take(n_words) {
        if pw == 0 {
            continue;
        }
        let c0 = w * 16 * 16;
        // SAFETY: the caller hoisted `lut_gather_active()` (AVX2
        // detected), and ntable is padded to whole words so
        // c0 + 256 <= nt.len().
        let (h0, h1) = unsafe { simd::lut_nibbles_pair(nt, c0, pw) };
        ga[w * 2] += mult * h0;
        ga[w * 2 + 1] += mult * h1;
    }
}

/// The MoBiQuant kernel: token-adaptive bit-sliced GEMV with shared
/// scales.  `active[e]` selects slices (active[0] must be true).
/// out: (d_out), overwritten.
///
/// Perf-tuned inner loop (EXPERIMENTS.md §Perf): per output channel the
/// plane words stream once, each u64 is split into 8 LUT bytes walked
/// with two independent accumulators per group quad (breaks the FP add
/// dependency chain), and all indexing is hoisted out of the byte loop.
pub fn gemv_lut(slices: &[PackedSlice], base: &GroupParams, lut: &TokenLut,
                active: &[bool], out: &mut [f32]) {
    debug_assert_eq!(out.len(), base.d_out);
    gemv_lut_range(slices, base, lut, active, 0, base.d_out, out);
}

/// d_out below which the fork-join dispatch cost of `parallel_chunks`
/// eats the win.  Re-derived for the persistent pool (EXPERIMENTS.md
/// §Runtime): a dispatch now costs a condvar wake + join (~2 µs, was
/// tens of µs of scoped thread spawns), so the break-even moved from
/// ~512 output channels down to ~128 — each worker still keeps enough
/// contiguous channels for the plane stream to amortize.
pub const PARALLEL_MIN_DOUT: usize = 128;

/// Runtime-overridable view of [`PARALLEL_MIN_DOUT`] (satellite of the
/// sharding PR): `MOBIQ_PARALLEL_MIN_DOUT` in the environment or
/// `ServerConfig.parallel_min_dout` moves the gate without a rebuild so
/// the first cargo-equipped session can tune it from measured
/// `perf_pool` dispatch latency.  Only the serial/parallel dispatch
/// decision moves; serial and pooled kernels are pinned bit-identical.
pub static PARALLEL_MIN_DOUT_GATE: TunableGate =
    TunableGate::new("MOBIQ_PARALLEL_MIN_DOUT", PARALLEL_MIN_DOUT);

/// `gemv_lut` parallelised over contiguous d_out chunks on the
/// persistent fork-join pool.  Falls back to the serial kernel for
/// size-1 pools or small layers where even the cheap dispatch
/// dominates.
pub fn gemv_lut_parallel(slices: &[PackedSlice], base: &GroupParams,
                         lut: &TokenLut, active: &[bool],
                         pool: &ThreadPool, out: &mut [f32]) {
    let d_out = base.d_out;
    debug_assert_eq!(out.len(), d_out);
    if pool.size() <= 1 || d_out < PARALLEL_MIN_DOUT_GATE.get() {
        return gemv_lut(slices, base, lut, active, out);
    }
    let optr = SharedOut(out.as_mut_ptr());
    pool.parallel_chunks(d_out, |o0, o1| {
        // SAFETY: parallel_chunks hands out disjoint o-ranges of
        // `out`, so each worker materialises &mut only over its own
        // cells.
        let rows = unsafe {
            std::slice::from_raw_parts_mut(optr.0.add(o0), o1 - o0)
        };
        gemv_lut_range(slices, base, lut, active, o0, o1, rows);
    });
}

/// Output-channel range core of [`gemv_lut`]: computes channels
/// `o0..o1` into `out` (len o1-o0).  The parallel wrappers partition
/// d_out across workers with this, and the tensor-parallel shard path
/// uses it directly as the column-sharded per-token entry point: each
/// output channel is accumulated entirely by one caller in the exact
/// order of the full kernel, so a column partition is bit-identical to
/// the unsharded GEMV for any shard count (already pinned by the
/// parallel-parity suite).
pub fn gemv_lut_range(slices: &[PackedSlice], base: &GroupParams,
                      lut: &TokenLut, active: &[bool], o0: usize, o1: usize,
                      out: &mut [f32]) {
    let d_out = base.d_out;
    let gs = base.group_size;
    let n_groups = base.n_groups;
    debug_assert!(active[0], "slice 0 is the shared expert");
    debug_assert_eq!(out.len(), o1 - o0);
    debug_assert!(gs % 8 == 0);
    let bytes_per_group = gs / 8;
    let n_words = slices[0].n_words;
    debug_assert!(n_groups <= 512 && n_words * 2 <= 512,
                  "group scratch cap");
    // per-group accumulators of sum_e 4^-e (p0 + 2 p1) masked sums
    let mut ga = [0f32; 512];

    // sum over active residual slices of 4^-e * (2^{b-1} - 0.5)
    let mut resid_c = 0f32;
    for (e, &a) in active.iter().enumerate().skip(1) {
        if a {
            resid_c += slice_weight(e, base.bits)
                * ((1u32 << (base.bits - 1)) as f32 - 0.5);
        }
    }

    // Hoisted SIMD dispatch (ISSUE 9): the AVX2 gather resolves a
    // whole plane word per instruction and reduces in the exact
    // pairwise tree of the scalar walk below, so both arms are
    // bit-identical (pinned by util::simd tests + tests/simd_parity).
    let gather = simd::lut_gather_active();

    let table = &lut.table[..];
    for o in o0..o1 {
        // padding words spill into ga[n_groups..2*n_words] with zero
        // contributions; clear them too so they cannot overflow
        ga[..n_groups.max(2 * n_words)].fill(0.0);
        for (e, &is_active) in active.iter().enumerate() {
            if !is_active {
                continue;
            }
            let sl = &slices[e];
            let we = slice_weight(e, base.bits);
            let mut mult = we;
            for p in 0..sl.slice_bits {
                let plane = sl.plane(p, o);
                if lut.nibble {
                    // nibble-table path: 16x smaller LUT stays cache-
                    // resident at large d_in.  bpg==4 only (gs 32).
                    assert_eq!(bytes_per_group, 4,
                               "nibble path requires group_size 32");
                    let nt = &lut.ntable[..];
                    if gather {
                        nibble_words_gather(plane, n_words, nt, mult,
                                            &mut ga);
                    } else {
                        nibble_words_scalar(plane, n_words, nt, mult,
                                            &mut ga);
                    }
                } else if bytes_per_group == 4 {
                    // hot configuration (group_size 32): two group-quads
                    // per word, unrolled with independent accumulators.
                    if gather {
                        byte_words_gather(plane, n_words, table, mult,
                                          &mut ga);
                    } else {
                        byte_words_scalar(plane, n_words, table, mult,
                                          &mut ga);
                    }
                } else {
                    // generic path: acc/g/b persist across words so any
                    // gs % 8 == 0 works.
                    let mut g = 0usize;
                    let mut b = 0usize;
                    let mut acc = 0f32;
                    for (w, &pw) in plane.iter().enumerate().take(n_words)
                    {
                        let mut word = pw;
                        let chunk0 = w * 8;
                        if word == 0 && b == 0 && bytes_per_group <= 8
                            && 8 % bytes_per_group == 0
                        {
                            g += 8 / bytes_per_group;
                            continue;
                        }
                        for i in 0..8 {
                            let byte = (word & 0xFF) as usize;
                            word >>= 8;
                            // SAFETY: table padded to whole words.
                            acc += unsafe {
                                *table.get_unchecked(
                                    (chunk0 + i) * 256 + byte)
                            };
                            b += 1;
                            if b == bytes_per_group {
                                ga[g] += mult * acc;
                                acc = 0.0;
                                b = 0;
                                g += 1;
                            }
                        }
                    }
                }
                mult *= 2.0;
            }
        }
        let srow = &base.scale[..];
        let zrow = &base.zero[..];
        let mut acc = 0f32;
        for g in 0..n_groups {
            let s1 = srow[g * d_out + o];
            let z1 = zrow[g * d_out + o];
            let c = (z1 - 0.5 + resid_c) * lut.group_sums[g];
            acc += s1 * (ga[g] - c);
        }
        out[o - o0] = acc;
    }
}

/// First-cut LUT kernel (per-group helper calls, checked indexing) —
/// kept as the §Perf "before" comparator; see EXPERIMENTS.md §Perf.
pub fn gemv_lut_simple(slices: &[PackedSlice], base: &GroupParams,
                       lut: &TokenLut, active: &[bool], out: &mut [f32]) {
    let d_out = base.d_out;
    let gs = base.group_size;
    let n_groups = base.n_groups;
    let mut resid_c = 0f32;
    for (e, &a) in active.iter().enumerate().skip(1) {
        if a {
            resid_c += slice_weight(e, base.bits)
                * ((1u32 << (base.bits - 1)) as f32 - 0.5);
        }
    }
    for o in 0..d_out {
        let mut acc = 0f32;
        for g in 0..n_groups {
            let mut a = 0f32;
            for (e, &is_active) in active.iter().enumerate() {
                if !is_active {
                    continue;
                }
                let sl = &slices[e];
                let mut qdot = 0f32;
                let mut mult = 1f32;
                for p in 0..sl.slice_bits {
                    qdot += mult
                        * lut.plane_group_sum(sl.plane(p, o), g, gs);
                    mult *= 2.0;
                }
                a += slice_weight(e, base.bits) * qdot;
            }
            let (s1, z1) = base.at(g, o);
            let c = (z1 - 0.5 + resid_c) * lut.group_sums[g];
            acc += s1 * (a - c);
        }
        out[o] = acc;
    }
}

/// Bit-iteration baseline: same math, but masked sums walk set bits with
/// trailing_zeros instead of byte LUTs.  Kept for the §Perf before/after.
pub fn gemv_bitserial(slices: &[PackedSlice], base: &GroupParams,
                      x: &[f32], group_sums: &[f32], active: &[bool],
                      out: &mut [f32]) {
    let d_out = base.d_out;
    let gs = base.group_size;
    let mut resid_c = 0f32;
    for (e, &a) in active.iter().enumerate().skip(1) {
        if a {
            resid_c += slice_weight(e, base.bits)
                * ((1u32 << (base.bits - 1)) as f32 - 0.5);
        }
    }
    for o in 0..d_out {
        let mut acc = 0f32;
        for g in 0..base.n_groups {
            let mut a = 0f32;
            for (e, &is_active) in active.iter().enumerate() {
                if !is_active {
                    continue;
                }
                let sl = &slices[e];
                let mut qdot = 0f32;
                let mut mult = 1f32;
                for p in 0..sl.slice_bits {
                    let plane = sl.plane(p, o);
                    let mut sum = 0f32;
                    let lo = g * gs;
                    let hi = (g + 1) * gs;
                    let mut row = lo;
                    while row < hi {
                        let word = plane[row / 64];
                        let base_bit = row % 64;
                        let span = (hi - row).min(64 - base_bit);
                        let mut m = (word >> base_bit)
                            & mask_lo(span);
                        while m != 0 {
                            let b = m.trailing_zeros() as usize;
                            sum += x[row + b];
                            m &= m - 1;
                        }
                        row += span;
                    }
                    qdot += mult * sum;
                    mult *= 2.0;
                }
                a += slice_weight(e, base.bits) * qdot;
            }
            let (s1, z1) = base.at(g, o);
            acc += s1 * (a - (z1 - 0.5 + resid_c) * group_sums[g]);
        }
        out[o] = acc;
    }
}

#[inline]
fn mask_lo(n: usize) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Correctness oracle: reconstruct the active slices' dense f32 weights
/// and do a plain GEMV.  O(d_in·d_out) floats — also the "offline
/// repacking" comparator (what MatQuant-style deployment would execute).
pub fn dequant_gemv(slices: &[PackedSlice], base: &GroupParams, x: &[f32],
                    active: &[bool], out: &mut [f32]) {
    let d_in = slices[0].d_in;
    let d_out = base.d_out;
    let mut w = vec![0f32; d_in * d_out];
    for (e, &is_active) in active.iter().enumerate() {
        if !is_active {
            continue;
        }
        let codes = slices[e].unpack();
        let deq = dequantize(&codes, &base.residual(e));
        for (wi, di) in w.iter_mut().zip(&deq) {
            *wi += di;
        }
    }
    matvec(&w, x, out, d_in, d_out);
}

/// Dense f32 GEMV helper: w is (d_in, d_out) row-major; y = x W.
pub fn matvec(w: &[f32], x: &[f32], out: &mut [f32], d_in: usize,
              d_out: usize) {
    out.fill(0.0);
    for (row, &xv) in x.iter().enumerate().take(d_in) {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[row * d_out..(row + 1) * d_out];
        for (o, wv) in wrow.iter().enumerate() {
            out[o] += xv * wv;
        }
    }
}

/// Column range of [`matvec`]: output channels `o0..o1` into the
/// compact `out` (len o1-o0).  Each channel accumulates over rows in
/// the same order as the full kernel (including the zero-activation
/// skip, which also preserves ±0.0 signs), so a column partition is
/// bit-identical to the unsharded GEMV — the dense-backend analogue of
/// [`gemv_lut_range`] for the tensor-parallel shard path.
pub fn matvec_range(w: &[f32], x: &[f32], d_in: usize, d_out: usize,
                    o0: usize, o1: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), o1 - o0);
    out.fill(0.0);
    for (row, &xv) in x.iter().enumerate().take(d_in) {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[row * d_out + o0..row * d_out + o1];
        for (ov, wv) in out.iter_mut().zip(wrow) {
            *ov += xv * wv;
        }
    }
}

/// Row-sharded (input-range) LUT-GEMM entry point: the partial
/// contribution of activation groups `g0..g1` to **all** `d_out`
/// channels.  Summing the partials of a disjoint group partition over
/// shards — e.g. with [`Communicator::all_reduce_sum`] — recovers the
/// full GEMV up to f32 reassociation (the per-channel sum is split at
/// group boundaries, so the result matches to ~1e-6 relative, not
/// bit-exactly; see `row_partials_sum_to_full`).  The exact sharded
/// transformer path therefore uses the column-range entries above; this
/// one exists for backends whose cost model favours row sharding
/// (smaller per-shard activation slices, one all-reduce join) and
/// accepts the reassociation.
///
/// [`Communicator::all_reduce_sum`]: crate::util::comm::Communicator::all_reduce_sum
pub fn gemv_lut_row_partial(slices: &[PackedSlice], base: &GroupParams,
                            lut: &TokenLut, active: &[bool], g0: usize,
                            g1: usize, out: &mut [f32]) {
    let d_out = base.d_out;
    let gs = base.group_size;
    debug_assert_eq!(out.len(), d_out);
    debug_assert!(active[0], "slice 0 is the shared expert");
    debug_assert!(g1 <= base.n_groups);
    let mut resid_c = 0f32;
    for (e, &a) in active.iter().enumerate().skip(1) {
        if a {
            resid_c += slice_weight(e, base.bits)
                * ((1u32 << (base.bits - 1)) as f32 - 0.5);
        }
    }
    for o in 0..d_out {
        let mut acc = 0f32;
        for g in g0..g1 {
            let mut a = 0f32;
            for (e, &is_active) in active.iter().enumerate() {
                if !is_active {
                    continue;
                }
                let sl = &slices[e];
                let mut qdot = 0f32;
                let mut mult = 1f32;
                for p in 0..sl.slice_bits {
                    qdot += mult
                        * lut.plane_group_sum(sl.plane(p, o), g, gs);
                    mult *= 2.0;
                }
                a += slice_weight(e, base.bits) * qdot;
            }
            let (s1, z1) = base.at(g, o);
            let c = (z1 - 0.5 + resid_c) * lut.group_sums[g];
            acc += s1 * (a - c);
        }
        out[o] = acc;
    }
}

/// Group tokens by identical slice masks — §4.3 token permutation.  The
/// returned permutation makes same-precision tokens contiguous so the
/// batched path streams each slice's planes once per token group.
pub fn permute_by_mask(masks: &[Vec<bool>]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..masks.len()).collect();
    let key = |m: &Vec<bool>| -> u32 {
        m.iter().enumerate()
            .fold(0u32, |acc, (i, &b)| acc | ((b as u32) << i))
    };
    idx.sort_by_key(|&i| key(&masks[i]));
    idx
}

/// Runs of identical masks after the §4.3 permutation: each returned
/// group lists original token indices sharing one routed slice mask.
pub fn mask_groups(masks: &[Vec<bool>]) -> Vec<Vec<usize>> {
    let perm = permute_by_mask(masks);
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for &i in &perm {
        match groups.last_mut() {
            Some(grp) if masks[grp[0]] == masks[i] => grp.push(i),
            _ => groups.push(vec![i]),
        }
    }
    groups
}

/// Batched weight-stationary scratch: one [`TokenLut`] table block,
/// routed slice mask and effective-bits record per token of a prefill /
/// coalesced-decode batch.  Blocks grow lazily to the largest batch seen
/// so the steady-state serving loop stays allocation-free.
pub struct BatchLut {
    pub luts: Vec<TokenLut>,
    pub masks: Vec<Vec<bool>>,
    /// Effective routed bits per token of the last forward_batch call.
    pub bits: Vec<usize>,
    d_in_cap: usize,
    group_size: usize,
}

impl BatchLut {
    pub fn new(d_in_cap: usize, group_size: usize) -> BatchLut {
        BatchLut {
            luts: Vec::new(),
            masks: Vec::new(),
            bits: Vec::new(),
            d_in_cap,
            group_size,
        }
    }

    /// Make room for a batch of `t` tokens (allocates only on growth).
    pub fn ensure_tokens(&mut self, t: usize) {
        while self.luts.len() < t {
            self.luts.push(TokenLut::new(self.d_in_cap, self.group_size));
            self.masks.push(Vec::new());
        }
    }

    /// Build token `i`'s LUT tables for activations `x`.
    pub fn build_token(&mut self, i: usize, x: &[f32],
                       group_size: usize) {
        self.luts[i].build(x, group_size);
    }

    /// Record token `i`'s routed slice mask.
    pub fn set_mask(&mut self, i: usize, mask: &[bool]) {
        self.masks[i].clear();
        self.masks[i].extend_from_slice(mask);
    }
}

/// The batched MoBiQuant kernel: §4.3 token permutation made
/// weight-stationary.  Tokens are grouped by identical routed slice
/// masks ([`mask_groups`]); within a group every plane word is streamed
/// **once** and resolved against all member tokens' LUT tables, so the
/// per-layer plane traffic drops from `O(T · plane_bytes)` to
/// `O(plane_bytes)` per mask group while the per-token math stays
/// bit-identical to [`gemv_lut`].
///
/// `batch` must hold built tables and masks for tokens `0..t`;
/// `out` is (t, d_out) row-major in the original token order.
pub fn gemm_lut_batch(slices: &[PackedSlice], base: &GroupParams,
                      batch: &BatchLut, t: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), t * base.d_out);
    if t == 0 {
        return;
    }
    let groups = mask_groups(&batch.masks[..t]);
    let optr = SharedOut(out.as_mut_ptr());
    for g in &groups {
        gemm_lut_group(slices, base, batch, g, 0, base.d_out, &optr);
    }
}

/// [`gemm_lut_batch`] parallelised over contiguous d_out chunks with
/// `ThreadPool::parallel_chunks`; every worker walks all mask groups over
/// its own output-channel range, so plane words still stream once per
/// (group, worker) and writes stay disjoint.
pub fn gemm_lut_batch_parallel(slices: &[PackedSlice],
                               base: &GroupParams, batch: &BatchLut,
                               t: usize, pool: &ThreadPool,
                               out: &mut [f32]) {
    let d_out = base.d_out;
    debug_assert_eq!(out.len(), t * d_out);
    if pool.size() <= 1 || d_out < PARALLEL_MIN_DOUT_GATE.get() {
        return gemm_lut_batch(slices, base, batch, t, out);
    }
    if t == 0 {
        return;
    }
    let groups = mask_groups(&batch.masks[..t]);
    let optr = SharedOut(out.as_mut_ptr());
    let groups = &groups;
    pool.parallel_chunks(d_out, |o0, o1| {
        for g in groups {
            gemm_lut_group(slices, base, batch, g, o0, o1, &optr);
        }
    });
}

/// Column-sharded batched entry point for the tensor-parallel path:
/// every mask group of tokens `0..t` resolved over output channels
/// `o0..o1` only, written at full `d_out` stride into the shared
/// buffer.  Per output channel the accumulation order is exactly that
/// of [`gemm_lut_batch`] (each channel is owned end-to-end by one
/// caller), so N shards covering disjoint column ranges reproduce the
/// unsharded batch bit-for-bit.  Callers guarantee disjoint (token, o)
/// cell sets across concurrent invocations.
pub fn gemm_lut_batch_range(slices: &[PackedSlice], base: &GroupParams,
                            batch: &BatchLut, t: usize, o0: usize,
                            o1: usize, out: &SharedOut) {
    if t == 0 || o0 == o1 {
        return;
    }
    let groups = mask_groups(&batch.masks[..t]);
    for g in &groups {
        gemm_lut_group(slices, base, batch, g, o0, o1, out);
    }
}

/// Weight-stationary core over one same-mask token group and one
/// output-channel range.  Writes out[tok * d_out + o] for o in o0..o1,
/// tok in `toks` — a disjoint cell set per (group, range) invocation.
fn gemm_lut_group(slices: &[PackedSlice], base: &GroupParams,
                  batch: &BatchLut, toks: &[usize], o0: usize, o1: usize,
                  out: &SharedOut) {
    let active = &batch.masks[toks[0]][..];
    let d_out = base.d_out;
    let gs = base.group_size;
    let n_groups = base.n_groups;
    debug_assert!(active[0], "slice 0 is the shared expert");
    debug_assert!(gs % 8 == 0);
    let bytes_per_group = gs / 8;
    let n_words = slices[0].n_words;
    let nt = toks.len();

    let nibble = batch.luts[toks[0]].nibble;
    debug_assert!(toks.iter().all(|&i| batch.luts[i].nibble == nibble),
                  "one batch = one activation width = one table regime");

    // Only the group_size-32 layouts have a weight-stationary inner
    // loop; other (cold) group sizes fall back to per-token range GEMVs
    // — same numerics, per-token plane traffic.
    if bytes_per_group != 4 {
        assert!(!nibble, "nibble path requires group_size 32");
        for &ti in toks {
            // SAFETY: each token's (row, o0..o1) cells are disjoint.
            let row = unsafe {
                std::slice::from_raw_parts_mut(
                    out.0.add(ti * d_out + o0), o1 - o0)
            };
            gemv_lut_range(slices, base, &batch.luts[ti], active, o0, o1,
                           row);
        }
        return;
    }

    // sum over active residual slices of 4^-e * (2^{b-1} - 0.5)
    let mut resid_c = 0f32;
    for (e, &a) in active.iter().enumerate().skip(1) {
        if a {
            resid_c += slice_weight(e, base.bits)
                * ((1u32 << (base.bits - 1)) as f32 - 0.5);
        }
    }

    // per-(token, group) accumulators, token-major; padding words spill
    // zero contributions into gstride > n_groups cells.  Heap-allocated
    // (unlike the per-token kernel's stack array) because nt*gstride can
    // reach 32K floats and each parallel worker needs its own copy; one
    // malloc per (group, worker) call is noise next to the plane stream.
    let gstride = n_groups.max(2 * n_words);
    let mut ga = vec![0f32; nt * gstride];
    // Hoisted SIMD dispatch (ISSUE 9) — same bit-identical gather as
    // the per-token kernel, so batch-vs-per-token stays assert_eq.
    let gather = simd::lut_gather_active();
    for o in o0..o1 {
        ga.fill(0.0);
        for (e, &is_active) in active.iter().enumerate() {
            if !is_active {
                continue;
            }
            let sl = &slices[e];
            let we = slice_weight(e, base.bits);
            let mut mult = we;
            for p in 0..sl.slice_bits {
                let plane = sl.plane(p, o);
                if nibble {
                    for (w, &pw) in plane.iter().enumerate().take(n_words)
                    {
                        if pw == 0 {
                            continue; // zero word: all LUT hits are 0
                        }
                        let c0 = w * 16 * 16;
                        if gather {
                            // gathered fast path: the word's nibble
                            // decode rides in the index vector
                            for (k, &ti) in toks.iter().enumerate() {
                                let ntab = &batch.luts[ti].ntable[..];
                                let gb = k * gstride + w * 2;
                                // SAFETY: gather ⇒ AVX2 detected;
                                // ntable is padded to whole words so
                                // c0 + 256 <= ntab.len().
                                let (h0, h1) = unsafe {
                                    simd::lut_nibbles_pair(ntab, c0,
                                                           pw)
                                };
                                ga[gb] += mult * h0;
                                ga[gb + 1] += mult * h1;
                            }
                            continue;
                        }
                        // split the word into 16 nibbles once, reused by
                        // every token in the group (weight-stationary)
                        let mut nib = [0usize; 16];
                        for (j, n) in nib.iter_mut().enumerate() {
                            *n = ((pw >> (4 * j)) & 0xF) as usize;
                        }
                        for (k, &ti) in toks.iter().enumerate() {
                            let ntab = &batch.luts[ti].ntable[..];
                            let gb = k * gstride + w * 2;
                            // SAFETY: ntable padded to whole words;
                            // nibble < 16 by construction.
                            unsafe {
                                let mut q0 = 0f32;
                                let mut q1 = 0f32;
                                let mut q2 = 0f32;
                                let mut q3 = 0f32;
                                for j in 0..4 {
                                    q0 += *ntab.get_unchecked(
                                        c0 + j * 16 + nib[j]);
                                    q1 += *ntab.get_unchecked(
                                        c0 + (4 + j) * 16 + nib[4 + j]);
                                    q2 += *ntab.get_unchecked(
                                        c0 + (8 + j) * 16 + nib[8 + j]);
                                    q3 += *ntab.get_unchecked(
                                        c0 + (12 + j) * 16 + nib[12 + j]);
                                }
                                *ga.get_unchecked_mut(gb) +=
                                    mult * (q0 + q1);
                                *ga.get_unchecked_mut(gb + 1) +=
                                    mult * (q2 + q3);
                            }
                        }
                    }
                } else {
                    for (w, &pw) in plane.iter().enumerate().take(n_words)
                    {
                        if pw == 0 {
                            continue;
                        }
                        let c0 = w * 8 * 256;
                        if gather {
                            for (k, &ti) in toks.iter().enumerate() {
                                let table = &batch.luts[ti].table[..];
                                let gb = k * gstride + w * 2;
                                // SAFETY: gather ⇒ AVX2 detected;
                                // table is padded to whole words so
                                // c0 + 2048 <= table.len().
                                let (h0, h1) = unsafe {
                                    simd::lut_bytes_pair(table, c0, pw)
                                };
                                ga[gb] += mult * h0;
                                ga[gb + 1] += mult * h1;
                            }
                            continue;
                        }
                        let mut by = [0usize; 8];
                        for (j, b) in by.iter_mut().enumerate() {
                            *b = ((pw >> (8 * j)) & 0xFF) as usize;
                        }
                        for (k, &ti) in toks.iter().enumerate() {
                            let table = &batch.luts[ti].table[..];
                            let gb = k * gstride + w * 2;
                            // SAFETY: table padded to whole words; byte
                            // offsets < 256 by construction.
                            unsafe {
                                let q0 = *table.get_unchecked(c0 + by[0])
                                    + *table.get_unchecked(
                                        c0 + 256 + by[1]);
                                let q1 = *table.get_unchecked(
                                    c0 + 512 + by[2])
                                    + *table.get_unchecked(
                                        c0 + 768 + by[3]);
                                let q2 = *table.get_unchecked(
                                    c0 + 1024 + by[4])
                                    + *table.get_unchecked(
                                        c0 + 1280 + by[5]);
                                let q3 = *table.get_unchecked(
                                    c0 + 1536 + by[6])
                                    + *table.get_unchecked(
                                        c0 + 1792 + by[7]);
                                *ga.get_unchecked_mut(gb) +=
                                    mult * (q0 + q1);
                                *ga.get_unchecked_mut(gb + 1) +=
                                    mult * (q2 + q3);
                            }
                        }
                    }
                }
                mult *= 2.0;
            }
        }
        // shared-scale writeback, one row cell per token
        let srow = &base.scale[..];
        let zrow = &base.zero[..];
        for (k, &ti) in toks.iter().enumerate() {
            let gsums = &batch.luts[ti].group_sums[..];
            let mut acc = 0f32;
            for g in 0..n_groups {
                let s1 = srow[g * d_out + o];
                let z1 = zrow[g * d_out + o];
                let c = (z1 - 0.5 + resid_c) * gsums[g];
                acc += s1 * (ga[k * gstride + g] - c);
            }
            // SAFETY: (ti, o) cells are disjoint across groups and
            // output-channel ranges.
            unsafe {
                *out.0.add(ti * d_out + o) = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{property, Pcg};

    fn setup(rng: &mut Pcg, d_in: usize, d_out: usize, gs: usize)
             -> (Vec<PackedSlice>, GroupParams) {
        let w = rng.normal_vec(d_in * d_out, 0.2);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = super::super::quantizer::decompose(&w, &base, 4);
        let slices = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        (slices, base)
    }

    /// `bits·e >= 64` used to left-shift a u64 out of range (panic in
    /// debug, wrap in release); the checked form pins the boundary.
    #[test]
    fn slice_weight_checked_shift_at_boundary() {
        assert_eq!(slice_weight(0, 2), 1.0);
        assert_eq!(slice_weight(1, 2), 0.25);
        // largest in-range shifts
        assert_eq!(slice_weight(31, 2), 1.0 / (1u64 << 62) as f32);
        assert_eq!(slice_weight(63, 1), 1.0 / (1u64 << 63) as f32);
        // at and past the u64 boundary: a hard 0.0, no overflow
        assert_eq!(slice_weight(32, 2), 0.0);
        assert_eq!(slice_weight(64, 1), 0.0);
        assert_eq!(slice_weight(16, 4), 0.0);
        assert_eq!(slice_weight(1000, 8), 0.0);
    }

    #[test]
    fn lut_matches_oracle() {
        property(20, 15, |rng, _| {
            let (d_in, d_out, gs) = (64, 24, 32);
            let (slices, base) = setup(rng, d_in, d_out, gs);
            let x = rng.normal_vec(d_in, 1.0);
            let mut active = vec![true, rng.bool(0.5), rng.bool(0.5),
                                  rng.bool(0.5)];
            active[0] = true;
            let mut lut = TokenLut::new(d_in, gs);
            lut.build(&x, gs);
            let mut y = vec![0f32; d_out];
            let mut y_ref = vec![0f32; d_out];
            gemv_lut(&slices, &base, &lut, &active, &mut y);
            dequant_gemv(&slices, &base, &x, &active, &mut y_ref);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 2e-3,
                        "lut {} vs oracle {}", a, b);
            }
        });
    }

    #[test]
    fn bitserial_matches_oracle() {
        property(21, 10, |rng, _| {
            let (d_in, d_out, gs) = (96, 16, 32);
            let (slices, base) = setup(rng, d_in, d_out, gs);
            let x = rng.normal_vec(d_in, 1.0);
            let active = vec![true, true, false, true];
            let group_sums: Vec<f32> = (0..d_in / gs)
                .map(|g| x[g * gs..(g + 1) * gs].iter().sum())
                .collect();
            let mut y = vec![0f32; d_out];
            let mut y_ref = vec![0f32; d_out];
            gemv_bitserial(&slices, &base, &x, &group_sums, &active,
                           &mut y);
            dequant_gemv(&slices, &base, &x, &active, &mut y_ref);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 2e-3);
            }
        });
    }

    #[test]
    fn more_slices_reduce_error() {
        let mut rng = Pcg::new(5);
        let (d_in, d_out, gs) = (64, 16, 32);
        let w = rng.normal_vec(d_in * d_out, 0.2);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = super::super::quantizer::decompose(&w, &base, 4);
        let slices: Vec<PackedSlice> = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        let x = rng.normal_vec(d_in, 1.0);
        let mut y_fp = vec![0f32; d_out];
        matvec(&w, &x, &mut y_fp, d_in, d_out);
        let mut lut = TokenLut::new(d_in, gs);
        lut.build(&x, gs);
        let mut prev = f64::INFINITY;
        for k in 1..=4 {
            let active: Vec<bool> = (0..4).map(|e| e < k).collect();
            let mut y = vec![0f32; d_out];
            gemv_lut(&slices, &base, &lut, &active, &mut y);
            let err: f64 = y.iter().zip(&y_fp)
                .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            assert!(err < prev, "k={}: {} !< {}", k, err, prev);
            prev = err;
        }
    }

    #[test]
    fn permutation_groups_masks() {
        let masks = vec![
            vec![true, false], vec![true, true], vec![true, false],
            vec![true, true], vec![true, false],
        ];
        let perm = permute_by_mask(&masks);
        // all equal masks contiguous
        let keys: Vec<bool> = perm.iter().map(|&i| masks[i][1]).collect();
        let first_true = keys.iter().position(|&b| b).unwrap();
        assert!(keys[first_true..].iter().all(|&b| b));
        // it is a permutation
        let mut sorted = perm.clone();
        sorted.sort();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn nibble_path_matches_oracle() {
        // d_in above NIBBLE_THRESHOLD exercises the nibble-table kernel
        property(23, 3, |rng, _| {
            let (d_in, d_out, gs) = (2048, 8, 32);
            let (slices, base) = setup(rng, d_in, d_out, gs);
            let x = rng.normal_vec(d_in, 1.0);
            let active = vec![true, true, false, true];
            let mut lut = TokenLut::new(d_in, gs);
            lut.build(&x, gs);
            assert!(lut.nibble, "threshold should select nibble tables");
            let mut y = vec![0f32; d_out];
            let mut y_ref = vec![0f32; d_out];
            gemv_lut(&slices, &base, &lut, &active, &mut y);
            dequant_gemv(&slices, &base, &x, &active, &mut y_ref);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 2e-2, "nibble {} vs {}", a, b);
            }
        });
    }

    #[test]
    fn lut_rebuild_smaller_then_larger() {
        // shared scratch across linears of different widths must not
        // leak stale table entries (regression test for the capacity
        // refactor)
        let mut rng = Pcg::new(8);
        let gs = 32;
        let (slices_big, base_big) = setup(&mut rng, 128, 8, gs);
        let (slices_small, base_small) = setup(&mut rng, 64, 8, gs);
        let mut lut = TokenLut::new(128, gs);
        let x_big = rng.normal_vec(128, 1.0);
        let x_small = rng.normal_vec(64, 1.0);
        let active = vec![true, true, true, true];
        let mut y = vec![0f32; 8];
        let mut y_ref = vec![0f32; 8];
        for _ in 0..3 {
            lut.build(&x_big, gs);
            gemv_lut(&slices_big, &base_big, &lut, &active, &mut y);
            dequant_gemv(&slices_big, &base_big, &x_big, &active,
                         &mut y_ref);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 2e-3);
            }
            lut.build(&x_small, gs);
            gemv_lut(&slices_small, &base_small, &lut, &active, &mut y);
            dequant_gemv(&slices_small, &base_small, &x_small, &active,
                         &mut y_ref);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn lut_simple_matches_optimized() {
        property(24, 10, |rng, _| {
            let (d_in, d_out, gs) = (96, 12, 32);
            let (slices, base) = setup(rng, d_in, d_out, gs);
            let x = rng.normal_vec(d_in, 1.0);
            let active = vec![true, rng.bool(0.5), rng.bool(0.5), true];
            let mut lut = TokenLut::new(d_in, gs);
            lut.build(&x, gs);
            let mut a = vec![0f32; d_out];
            let mut b = vec![0f32; d_out];
            gemv_lut(&slices, &base, &lut, &active, &mut a);
            gemv_lut_simple(&slices, &base, &lut, &active, &mut b);
            for (x1, x2) in a.iter().zip(&b) {
                assert!((x1 - x2).abs() < 1e-3);
            }
        });
    }

    /// Random per-token masks (slice 0 always on) + built BatchLut.
    fn setup_batch(rng: &mut Pcg, d_in: usize, gs: usize, t: usize,
                   xs: &[f32]) -> BatchLut {
        let mut batch = BatchLut::new(d_in, gs);
        batch.ensure_tokens(t);
        for i in 0..t {
            let mask = vec![true, rng.bool(0.5), rng.bool(0.5),
                            rng.bool(0.5)];
            batch.set_mask(i, &mask);
            batch.build_token(i, &xs[i * d_in..(i + 1) * d_in], gs);
        }
        batch
    }

    #[test]
    fn batch_matches_per_token_kernel() {
        // the weight-stationary kernel must be bit-identical to gemv_lut
        // on the fast (group_size 32) path
        property(30, 8, |rng, _| {
            let (d_in, d_out, gs) = (96, 24, 32);
            let (slices, base) = setup(rng, d_in, d_out, gs);
            let t = 1 + rng.below(9); // ragged T, including T=1
            let xs = rng.normal_vec(d_in * t, 1.0);
            let batch = setup_batch(rng, d_in, gs, t, &xs);
            let mut out = vec![0f32; t * d_out];
            gemm_lut_batch(&slices, &base, &batch, t, &mut out);
            let mut lut = TokenLut::new(d_in, gs);
            let mut y = vec![0f32; d_out];
            for i in 0..t {
                lut.build(&xs[i * d_in..(i + 1) * d_in], gs);
                gemv_lut(&slices, &base, &lut, &batch.masks[i], &mut y);
                assert_eq!(&out[i * d_out..(i + 1) * d_out], &y[..],
                           "token {i} diverged from per-token kernel");
            }
        });
    }

    #[test]
    fn batch_parallel_matches_serial() {
        let mut rng = Pcg::new(31);
        let (d_in, d_out, gs) = (64, 600, 32); // d_out > PARALLEL_MIN_DOUT
        let (slices, base) = setup(&mut rng, d_in, d_out, gs);
        let t = 5;
        let xs = rng.normal_vec(d_in * t, 1.0);
        let batch = setup_batch(&mut rng, d_in, gs, t, &xs);
        let mut serial = vec![0f32; t * d_out];
        let mut par = vec![0f32; t * d_out];
        gemm_lut_batch(&slices, &base, &batch, t, &mut serial);
        let pool = ThreadPool::new(3);
        gemm_lut_batch_parallel(&slices, &base, &batch, t, &pool,
                                &mut par);
        assert_eq!(serial, par);
    }

    #[test]
    fn gemv_parallel_matches_serial() {
        let mut rng = Pcg::new(32);
        let (d_in, d_out, gs) = (64, 640, 32);
        let (slices, base) = setup(&mut rng, d_in, d_out, gs);
        let x = rng.normal_vec(d_in, 1.0);
        let active = vec![true, true, false, true];
        let mut lut = TokenLut::new(d_in, gs);
        lut.build(&x, gs);
        let mut serial = vec![0f32; d_out];
        let mut par = vec![0f32; d_out];
        gemv_lut(&slices, &base, &lut, &active, &mut serial);
        let pool = ThreadPool::new(4);
        gemv_lut_parallel(&slices, &base, &lut, &active, &pool, &mut par);
        assert_eq!(serial, par);
    }

    #[test]
    fn column_ranges_match_full_kernels() {
        // the shard entry points: disjoint column ranges must reassemble
        // the full per-token, batched, and dense outputs bit-for-bit,
        // including ragged splits that don't divide d_out
        property(33, 6, |rng, _| {
            let (d_in, d_out, gs) = (96, 24, 32);
            let (slices, base) = setup(rng, d_in, d_out, gs);
            let x = rng.normal_vec(d_in, 1.0);
            let active = vec![true, rng.bool(0.5), true, rng.bool(0.5)];
            let mut lut = TokenLut::new(d_in, gs);
            lut.build(&x, gs);
            let mut full = vec![0f32; d_out];
            gemv_lut(&slices, &base, &lut, &active, &mut full);
            for cuts in [vec![0, 24], vec![0, 7, 24], vec![0, 5, 16, 24]] {
                let mut stitched = vec![0f32; d_out];
                for w in cuts.windows(2) {
                    gemv_lut_range(&slices, &base, &lut, &active, w[0],
                                   w[1], &mut stitched[w[0]..w[1]]);
                }
                assert_eq!(full, stitched, "cuts {cuts:?}");
            }

            // batched entry: strided writes into one shared buffer
            let t = 1 + rng.below(5);
            let xs = rng.normal_vec(d_in * t, 1.0);
            let batch = setup_batch(rng, d_in, gs, t, &xs);
            let mut bfull = vec![0f32; t * d_out];
            gemm_lut_batch(&slices, &base, &batch, t, &mut bfull);
            let mut bst = vec![0f32; t * d_out];
            let optr = SharedOut(bst.as_mut_ptr());
            for w in [0usize, 9, 24].windows(2) {
                gemm_lut_batch_range(&slices, &base, &batch, t, w[0],
                                     w[1], &optr);
            }
            assert_eq!(bfull, bst);

            // dense entry
            let w = rng.normal_vec(d_in * d_out, 0.2);
            let mut dfull = vec![0f32; d_out];
            matvec(&w, &x, &mut dfull, d_in, d_out);
            let mut dst = vec![0f32; d_out];
            for c in [0usize, 11, 24].windows(2) {
                matvec_range(&w, &x, d_in, d_out, c[0], c[1],
                             &mut dst[c[0]..c[1]]);
            }
            assert_eq!(dfull, dst);
        });
    }

    #[test]
    fn row_partials_sum_to_full() {
        // the row-sharded entry composes by summation (all_reduce
        // semantics): approximate, not bit-exact — the split reassociates
        // each channel's f32 sum at the group boundary
        let mut rng = Pcg::new(34);
        let (d_in, d_out, gs) = (128, 16, 32);
        let (slices, base) = setup(&mut rng, d_in, d_out, gs);
        let x = rng.normal_vec(d_in, 1.0);
        let active = vec![true, true, false, true];
        let mut lut = TokenLut::new(d_in, gs);
        lut.build(&x, gs);
        let mut full = vec![0f32; d_out];
        gemv_lut_simple(&slices, &base, &lut, &active, &mut full);
        let n_groups = base.n_groups;
        let mut sum = vec![0f32; d_out];
        let mut part = vec![0f32; d_out];
        for w in [0, n_groups / 3, n_groups / 2 + 1, n_groups].windows(2) {
            gemv_lut_row_partial(&slices, &base, &lut, &active, w[0],
                                 w[1], &mut part);
            for (s, p) in sum.iter_mut().zip(&part) {
                *s += p;
            }
        }
        for (a, b) in sum.iter().zip(&full) {
            assert!((a - b).abs() < 1e-4,
                    "row partials {a} vs full {b}");
        }
        // degenerate single shard covers every group: exactly the
        // simple kernel's order, so bit-equal
        gemv_lut_row_partial(&slices, &base, &lut, &active, 0, n_groups,
                             &mut part);
        assert_eq!(part, full);
    }

    #[test]
    fn gate_override_moves_dispatch_not_bits() {
        // forcing the gate to 0 (always parallel) and usize::MAX (never)
        // must not change one output bit — the gate only moves dispatch.
        // Safe against concurrent suites for the same reason.
        let mut rng = Pcg::new(35);
        let (d_in, d_out, gs) = (64, 96, 32); // below the default gate
        let (slices, base) = setup(&mut rng, d_in, d_out, gs);
        let x = rng.normal_vec(d_in, 1.0);
        let active = vec![true, true, true, false];
        let mut lut = TokenLut::new(d_in, gs);
        lut.build(&x, gs);
        let mut serial = vec![0f32; d_out];
        gemv_lut(&slices, &base, &lut, &active, &mut serial);
        let pool = ThreadPool::new(3);
        let mut forced = vec![0f32; d_out];
        PARALLEL_MIN_DOUT_GATE.set(0);
        gemv_lut_parallel(&slices, &base, &lut, &active, &pool,
                          &mut forced);
        assert_eq!(serial, forced, "forced-parallel dispatch");
        PARALLEL_MIN_DOUT_GATE.set(usize::MAX);
        gemv_lut_parallel(&slices, &base, &lut, &active, &pool,
                          &mut forced);
        assert_eq!(serial, forced, "forced-serial dispatch");
        PARALLEL_MIN_DOUT_GATE.clear();
        if std::env::var(PARALLEL_MIN_DOUT_GATE.env_var()).is_err() {
            assert_eq!(PARALLEL_MIN_DOUT_GATE.get(), PARALLEL_MIN_DOUT);
        }
    }

    #[test]
    fn mask_groups_partition_tokens() {
        let masks = vec![
            vec![true, false], vec![true, true], vec![true, false],
            vec![true, true], vec![true, false],
        ];
        let groups = mask_groups(&masks);
        assert_eq!(groups.len(), 2);
        let mut all: Vec<usize> = groups.concat();
        all.sort();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        for g in &groups {
            assert!(g.iter().all(|&i| masks[i] == masks[g[0]]));
        }
    }

    #[test]
    fn lut_build_partial_sums() {
        let mut lut = TokenLut::new(8, 8);
        let x = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        lut.build(&x, 8);
        // byte 0b10110001 selects x0 + x4 + x5 + x7 = 1+16+32+128
        assert_eq!(lut.table[0b1011_0001], 177.0);
        assert_eq!(lut.table[0], 0.0);
        assert_eq!(lut.table[255], x.iter().sum::<f32>());
        assert_eq!(lut.group_sums[0], 255.0);
    }
}
