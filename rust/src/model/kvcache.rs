//! Per-sequence KV cache with head-major slab allocation.
//!
//! The coordinator serves many concurrent sequences; each gets a cache
//! slot sized to max_seq_len.  The manager tracks allocation so the
//! scheduler can apply backpressure when memory runs out (Fig. 7-style
//! memory accounting feeds from here too).
//!
//! Layout: `[kv_head][pos][head_dim]` slabs (head-major), not the
//! position-major `[pos][kv_head * head_dim]` rows a naive append
//! would suggest.  The attention kernel walks one head's keys/values
//! over *many* positions (`model/attention.rs`), so head-major keeps
//! its score and value loops streaming contiguous memory; the layout
//! cost is paid once, as a strided scatter when a block of fresh K/V
//! rows lands (the fused RoPE writer `attention::append_kv_block`, or
//! `push` on the scalar-oracle path).

/// KV tensors of one sequence, one layer:
/// `(n_kv_heads, max_seq, head_dim)` slabs for K and V.
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, n_kv_heads: usize,
               head_dim: usize) -> KvCache {
        KvCache {
            k: vec![0f32; n_kv_heads * max_seq * head_dim],
            v: vec![0f32; n_kv_heads * max_seq * head_dim],
            len: 0,
            n_kv_heads,
            head_dim,
            max_seq,
        }
    }

    /// Row width of one position across all kv heads.
    pub fn width(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Claim `t` fresh positions; returns the first.  Callers write the
    /// claimed rows through the `*_row_mut` accessors (or the block
    /// writers below) — this is what lets the prefill path land QKV
    /// results in the slab directly instead of staging row copies.
    pub fn reserve(&mut self, t: usize) -> usize {
        assert!(self.len + t <= self.max_seq, "kv cache overflow");
        let pos = self.len;
        self.len += t;
        pos
    }

    /// Append one position's head-interleaved `(n_kv_heads * head_dim)`
    /// K/V rows (the scalar-oracle path); returns the position index.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> usize {
        let hd = self.head_dim;
        debug_assert_eq!(k_row.len(), self.width());
        debug_assert_eq!(v_row.len(), self.width());
        let pos = self.reserve(1);
        for h in 0..self.n_kv_heads {
            let base = self.slab_off(h, pos);
            self.k[base..base + hd]
                .copy_from_slice(&k_row[h * hd..(h + 1) * hd]);
            self.v[base..base + hd]
                .copy_from_slice(&v_row[h * hd..(h + 1) * hd]);
        }
        pos
    }

    #[inline]
    fn slab_off(&self, h: usize, pos: usize) -> usize {
        (h * self.max_seq + pos) * self.head_dim
    }

    /// Head `h`'s contiguous `(len, head_dim)` key slab.
    #[inline]
    pub fn k_head(&self, h: usize) -> &[f32] {
        let lo = h * self.max_seq * self.head_dim;
        &self.k[lo..lo + self.len * self.head_dim]
    }

    /// Head `h`'s contiguous `(len, head_dim)` value slab.
    #[inline]
    pub fn v_head(&self, h: usize) -> &[f32] {
        let lo = h * self.max_seq * self.head_dim;
        &self.v[lo..lo + self.len * self.head_dim]
    }

    #[inline]
    pub fn k_head_at(&self, h: usize, pos: usize) -> &[f32] {
        let lo = self.slab_off(h, pos);
        &self.k[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn v_head_at(&self, h: usize, pos: usize) -> &[f32] {
        let lo = self.slab_off(h, pos);
        &self.v[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn k_head_row_mut(&mut self, h: usize, pos: usize) -> &mut [f32] {
        let lo = self.slab_off(h, pos);
        &mut self.k[lo..lo + self.head_dim]
    }

    #[inline]
    pub fn v_head_row_mut(&mut self, h: usize, pos: usize) -> &mut [f32] {
        let lo = self.slab_off(h, pos);
        &mut self.v[lo..lo + self.head_dim]
    }

    pub fn nbytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// All layers' caches for one sequence.
pub struct SequenceKv {
    pub layers: Vec<KvCache>,
}

impl SequenceKv {
    pub fn new(n_layers: usize, max_seq: usize, n_kv_heads: usize,
               head_dim: usize) -> SequenceKv {
        SequenceKv {
            layers: (0..n_layers)
                .map(|_| KvCache::new(max_seq, n_kv_heads, head_dim))
                .collect(),
        }
    }
    pub fn len(&self) -> usize {
        self.layers.first().map(|c| c.len).unwrap_or(0)
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn reset(&mut self) {
        for c in &mut self.layers {
            c.reset();
        }
    }
    pub fn nbytes(&self) -> usize {
        self.layers.iter().map(|c| c.nbytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut c = KvCache::new(4, 1, 2);
        assert_eq!(c.push(&[1.0, 2.0], &[3.0, 4.0]), 0);
        assert_eq!(c.push(&[5.0, 6.0], &[7.0, 8.0]), 1);
        assert_eq!(c.k_head_at(0, 0), &[1.0, 2.0]);
        assert_eq!(c.v_head_at(0, 1), &[7.0, 8.0]);
        assert_eq!(c.k_head(0), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(c.len, 2);
        c.reset();
        assert_eq!(c.len, 0);
    }

    #[test]
    fn head_major_scatter() {
        // 2 kv heads x head_dim 2: interleaved rows land in per-head
        // slabs, contiguous over positions.
        let mut c = KvCache::new(3, 2, 2);
        c.push(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        c.push(&[10.0, 20.0, 30.0, 40.0], &[50.0, 60.0, 70.0, 80.0]);
        assert_eq!(c.k_head(0), &[1.0, 2.0, 10.0, 20.0]);
        assert_eq!(c.k_head(1), &[3.0, 4.0, 30.0, 40.0]);
        assert_eq!(c.v_head(0), &[5.0, 6.0, 50.0, 60.0]);
        assert_eq!(c.v_head(1), &[7.0, 8.0, 70.0, 80.0]);
    }

    #[test]
    fn reserve_claims_positions() {
        let mut c = KvCache::new(6, 1, 2);
        assert_eq!(c.reserve(4), 0);
        assert_eq!(c.len, 4);
        assert_eq!(c.reserve(2), 4);
        assert_eq!(c.len, 6);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1);
        c.push(&[0.0], &[0.0]);
        c.push(&[0.0], &[0.0]);
    }

    #[test]
    fn sequence_kv_sizes() {
        let s = SequenceKv::new(3, 8, 2, 2);
        assert_eq!(s.len(), 0);
        assert_eq!(s.nbytes(), 3 * 2 * 8 * 4 * 4);
    }
}
