"""Model / quantization configuration for the MoBiQuant reproduction.

The paper evaluates LLaMA-2-7B/13B, LLaMA-3-8B and LLaMA-3.2-1B/3B.  Those
checkpoints (and the A100s to run them) are not available in this environment,
so we substitute a family of LLaMA-architecture transformers pretrained from
scratch on synthetic corpora (see DESIGN.md §2).  The mapping used throughout
the benches:

    tiny-s   <->  LLaMA-3.2-1B   (smallest member)
    tiny-m   <->  LLaMA-2-7B     (default / headline model)
    tiny-l   <->  LLaMA-2-13B
    tiny-gqa <->  Mistral-7B     (grouped-query attention, App. E.2)
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style decoder-only transformer dimensions."""

    name: str = "tiny-m"
    vocab_size: int = 256          # byte-level tokenizer
    d_model: int = 160
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4            # < n_heads => grouped-query attention
    d_ff: int = 448                # SwiGLU hidden size
    max_seq_len: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = (
            2 * d * d                                   # wq, wo
            + 2 * d * (self.n_kv_heads * self.head_dim) # wk, wv
            + 3 * d * f                                 # gate, up, down
            + 2 * d                                     # norms
        )
        return v * d + self.n_layers * per_layer + d + d * v

    def linear_names(self) -> List[str]:
        return ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """MoBiSlice / MoBiRoute hyper-parameters (paper §4, App. C.1)."""

    n_slices: int = 4              # E
    slice_bits: int = 2            # b_e (uniform, paper default "2 2 2 2")
    group_size: int = 32           # input-dim group for shared scales
                                   # (paper uses 128 at d=4096; scaled down)
    router_hidden: int = 16        # 2-layer MLP hidden width
    target_bits: float = 3.0       # training target budget b (App. D.3)
    init_bits: float = 8.0         # b_init in the budget schedule (Eq. 7)
    reg_lambda: float = 1.0e-3     # lambda in Eq. 9
    epochs: int = 24               # per-layer calibration epochs (Alg. 1)
    stage1_epochs: int = 10        # first-slice stabilisation epochs
    nsamples: int = 96             # calibration sequences
    seq_len: int = 128             # calibration sequence length
    lwc_lr: float = 5.0e-3         # learnable-weight-clipping LR
    mobi_lr: float = 2.0e-3        # router + slice params LR
    schedule: str = "log"          # budget schedule (App. D.2)

    @property
    def max_bits(self) -> int:
        return self.n_slices * self.slice_bits

    @property
    def base_bits(self) -> int:
        return self.slice_bits       # shared-expert MSB slice


MODEL_ZOO = {
    "tiny-s": ModelConfig(name="tiny-s", d_model=96, n_layers=2, n_heads=4,
                          n_kv_heads=4, d_ff=256),
    "tiny-m": ModelConfig(name="tiny-m"),
    "tiny-l": ModelConfig(name="tiny-l", d_model=224, n_layers=6, n_heads=4,
                          n_kv_heads=4, d_ff=608),
    "tiny-gqa": ModelConfig(name="tiny-gqa", d_model=160, n_layers=4,
                            n_heads=4, n_kv_heads=2, d_ff=448),
}

# Pretraining step budget per model (1-core CPU budget; see DESIGN.md).
PRETRAIN_STEPS = {"tiny-s": 400, "tiny-m": 700, "tiny-l": 700, "tiny-gqa": 500}
