//! Server facade: owns the model and runs the scheduler on a dedicated
//! thread; clients submit prompts and receive responses over channels.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::Result;

use super::batcher::Batcher;
use super::controller::{ControllerConfig, ElasticController};
use super::metrics::Metrics;
use super::pressure::PressureConfig;
use super::request::{Request, RequestId, Response};
use super::scheduler::Scheduler;
use crate::model::kvcache::KvPrecision;
use crate::model::{Model, SpecConfig};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_active: usize,
    pub max_queue: usize,
    /// Prompt tokens prefetched per tick per sequence (one batched
    /// kernel call per chunk).
    pub prefill_chunk: usize,
    /// Cap on sequences fused into one coalesced decode call.
    pub max_decode_batch: usize,
    /// KV arena budget in f32-page equivalents.  `None` = worst case
    /// for `max_active` full-context sequences (no page pressure);
    /// `Some(p)` commits less memory and queues requests when bytes
    /// run short.  Quantized pages draw proportionally less of the
    /// budget, so an i8 deployment admits ~4x the sequences under the
    /// same number.
    pub kv_page_budget: Option<usize>,
    /// Default storage precision of admitted sequences' KV pages
    /// (requests submitted via [`Server::submit_at`] override it).
    pub kv_precision: KvPrecision,
    pub controller: ControllerConfig,
    /// Occupancy bands of the memory-pressure degradation ladder
    /// (admission floors, in-place tail requant, preemption).
    pub pressure: PressureConfig,
    /// External resource pressure in [0, 1] sampled each tick via the
    /// shared cell (set by the embedder, e.g. from a workload trace).
    pub initial_pressure: f64,
    /// Self-speculative decoding for the coalesced decode tick: `Some`
    /// drafts every decode group with a low-bit slice mask and verifies
    /// in one batched full-precision step (greedy outputs stay
    /// bit-identical to plain decode); `None` (the default) keeps the
    /// one-token-per-tick decode.
    pub speculative: Option<SpecConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_active: 4,
            max_queue: 64,
            prefill_chunk: 16,
            max_decode_batch: 32,
            kv_page_budget: None,
            kv_precision: KvPrecision::F32,
            controller: ControllerConfig::default(),
            pressure: PressureConfig::default(),
            initial_pressure: 0.0,
            speculative: None,
        }
    }
}

enum Msg {
    Req(Request),
    SetPressure(f64),
    Shutdown(mpsc::Sender<Metrics>),
}

pub struct Server {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<AtomicU64>,
    handle: Option<thread::JoinHandle<()>>,
    kv_precision: KvPrecision,
}

impl Server {
    /// Takes ownership of the model; the scheduler thread drives it.
    pub fn start(model: Model, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let kv_precision = cfg.kv_precision;
        let handle = thread::Builder::new()
            .name("mobiq-scheduler".into())
            .spawn(move || Self::run(model, cfg, rx))
            .expect("spawn scheduler");
        Server {
            tx,
            next_id: Arc::new(AtomicU64::new(0)),
            handle: Some(handle),
            kv_precision,
        }
    }

    fn run(model: Model, cfg: ServerConfig, rx: mpsc::Receiver<Msg>) {
        let mut batcher = Batcher::new(cfg.max_active, cfg.max_queue)
            .with_chunking(cfg.prefill_chunk, cfg.max_decode_batch);
        if let Some(pages) = cfg.kv_page_budget {
            batcher = batcher.with_kv_budget(pages);
        }
        if let Some(spec) = cfg.speculative.clone() {
            batcher = batcher.with_speculative(spec);
        }
        let controller = ElasticController::new(cfg.controller.clone());
        let mut sched = Scheduler::new(&model, batcher, controller)
            .with_pressure(cfg.pressure.clone());
        let mut pressure = cfg.initial_pressure;
        loop {
            // drain control/requests without blocking while busy
            loop {
                let msg = if sched.idle() {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => return,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                };
                match msg {
                    Msg::Req(r) => sched.submit(r),
                    Msg::SetPressure(p) => pressure = p,
                    Msg::Shutdown(reply) => {
                        let _ = reply.send(sched.metrics.clone());
                        return;
                    }
                }
            }
            if let Err(e) = sched.tick(pressure) {
                eprintln!("scheduler error: {e:#}");
                return;
            }
        }
    }

    /// Submit a prompt at the server's default KV storage precision;
    /// returns (id, receiver for the response).
    pub fn submit(&self, prompt: Vec<u32>, max_new_tokens: usize)
                  -> (RequestId, mpsc::Receiver<Response>) {
        self.submit_at(prompt, max_new_tokens, self.kv_precision)
    }

    /// Submit a prompt with an explicit per-request KV storage
    /// precision (the elastic analogue for the cache: a latency-
    /// tolerant request can run its KV at i8/i4 and draw a fraction of
    /// the arena budget).
    pub fn submit_at(&self, prompt: Vec<u32>, max_new_tokens: usize,
                     kv_precision: KvPrecision)
                     -> (RequestId, mpsc::Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Req(Request {
            id,
            prompt,
            max_new_tokens,
            kv_precision,
            submitted: Instant::now(),
            reply: tx,
        }));
        (id, rx)
    }

    /// Update the external resource-pressure signal (0 = calm, 1 = starved).
    pub fn set_pressure(&self, p: f64) {
        let _ = self.tx.send(Msg::SetPressure(p));
    }

    /// Graceful shutdown; returns final metrics.
    pub fn shutdown(mut self) -> Result<Metrics> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Shutdown(tx))
            .map_err(|_| anyhow::anyhow!("scheduler already gone"))?;
        let metrics = rx.recv()?;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        Ok(metrics)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let (tx, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(tx));
            let _ = h.join();
        }
    }
}
