//! Data pipeline: corpora, tokenization, evaluation suites, workloads.

pub mod cloze;
pub mod corpus;
pub mod ppl;
pub mod tokenizer;
pub mod workload;
