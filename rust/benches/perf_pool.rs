//! §Runtime — persistent fork-join pool study (EXPERIMENTS.md
//! §Runtime).
//!
//! Three questions, answered at serving-relevant shapes:
//!
//! 1. **Dispatch latency** — what does one fork-join cost on the
//!    persistent pool (condvar wake + join) vs the scoped-spawn
//!    baseline the pool replaced (fresh OS threads per call)?
//!    Measured with empty and near-empty bodies across grain sizes,
//!    this is the number the parallel gates are derived from.
//! 2. **GEMV gate sweep** — serial `gemv_lut` vs the pooled wrapper
//!    across d_out, bracketing `PARALLEL_MIN_DOUT` (128): below the
//!    gate the wrapper must cost ~nothing over serial (fallback), above
//!    it the speedup should approach the worker count.
//! 3. **Attention gate sweep** — single-query decode attention across
//!    context lengths bracketing `ATTN_PARALLEL_MIN_WORK` (2^14), the
//!    shape the cross-slot decode dispatch relies on.
//!
//! Writes `target/bench_reports/BENCH_pool.json`.

use std::sync::Arc;
use std::thread;

use mobiquant::bench_support::synth_mobiq_linear;
use mobiquant::mobiq::engine::{Precision, Scratch};
use mobiquant::mobiq::gemv::PARALLEL_MIN_DOUT;
use mobiquant::model::attention::{attention_block, AttnScratch,
                                  ATTN_PARALLEL_MIN_WORK};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::weights::ModelConfig;
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;
use mobiquant::util::threadpool::{default_threads, ThreadPool};

/// The scoped-spawn fork-join the persistent pool replaced: spawn
/// `lanes` fresh OS threads, split `0..n` dynamically, join.
fn scoped_parallel_for(lanes: usize, n: usize,
                       f: impl Fn(usize) + Sync) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let counter = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..lanes.min(n) {
            let counter = &counter;
            let f = &f;
            scope.spawn(move || loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

fn attn_cfg(n_heads: usize, n_kv: usize, hd: usize,
            ctx: usize) -> ModelConfig {
    ModelConfig {
        name: "bench".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads: n_kv,
        d_ff: 16,
        max_seq_len: ctx,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

fn main() {
    let mut suite = Suite::new("BENCH_pool");
    suite.header();
    let lanes = default_threads();
    let pool = Arc::new(ThreadPool::new(lanes));
    pool.warm();
    suite.note(&format!("pool size {lanes} (cores - 1)"));
    let mut rng = Pcg::new(23);

    // -- 1. dispatch latency: empty + tiny-grain bodies ----------------
    let ns_empty_pool = suite.bench("dispatch empty persistent", || {
        pool.parallel_chunks(lanes, |_, _| {});
    });
    let ns_empty_scope = suite.bench("dispatch empty scoped-spawn", || {
        scoped_parallel_for(lanes, lanes, |_| {});
    });
    suite.row("dispatch summary", &[
        ("ns_persistent", ns_empty_pool),
        ("ns_scoped_spawn", ns_empty_scope),
        ("spawn_over_persistent", ns_empty_scope / ns_empty_pool),
    ]);

    // grain sweep: fixed 256 KiB of f32 mul-adds split into `chunks`
    // range items — small grains expose dispatch+claim overhead
    let total = 1usize << 16;
    let src: Vec<f32> = rng.normal_vec(total, 1.0);
    let mut dst = vec![0f32; total];
    for &chunks in &[4usize, 16, 64, 256] {
        let grain = total / chunks;
        let label = format!("grain {grain} x {chunks}");
        let dptr = mobiquant::util::threadpool::SharedMut(
            dst.as_mut_ptr());
        let ns_pool = suite.bench(&format!("{label} persistent"), || {
            pool.parallel_for(chunks, |c| {
                // SAFETY: disjoint chunk ranges per index
                let out = unsafe {
                    std::slice::from_raw_parts_mut(
                        dptr.0.add(c * grain), grain)
                };
                for (o, s) in out.iter_mut()
                    .zip(&src[c * grain..(c + 1) * grain]) {
                    *o = s * 1.0001 + 0.5;
                }
            });
            black_box(());
        });
        let ns_scope = suite.bench(&format!("{label} scoped-spawn"),
                                   || {
            scoped_parallel_for(lanes, chunks, |c| {
                // SAFETY: disjoint chunk ranges per index
                let out = unsafe {
                    std::slice::from_raw_parts_mut(
                        dptr.0.add(c * grain), grain)
                };
                for (o, s) in out.iter_mut()
                    .zip(&src[c * grain..(c + 1) * grain]) {
                    *o = s * 1.0001 + 0.5;
                }
            });
            black_box(());
        });
        suite.row(&format!("{label} summary"), &[
            ("ns_persistent", ns_pool),
            ("ns_scoped_spawn", ns_scope),
            ("spawn_over_persistent", ns_scope / ns_pool),
        ]);
    }
    black_box(dst[0]);

    // -- 2. GEMV gate sweep (PARALLEL_MIN_DOUT bracketing) -------------
    suite.note(&format!("PARALLEL_MIN_DOUT = {PARALLEL_MIN_DOUT}"));
    let d_in = 1024usize;
    for &d_out in &[64usize, 128, 256, 512, 1024] {
        let lin = synth_mobiq_linear(&mut rng, d_in, d_out);
        let x = rng.normal_vec(d_in, 1.0);
        let mut out = vec![0f32; d_out];
        let prec = Precision::Fixed(2);
        let mut sc_serial = Scratch::new(d_in, 32, 8, 4);
        let mut sc_pool = Scratch::new(d_in, 32, 8, 4)
            .with_pool(Arc::clone(&pool));
        let ns_serial = suite.bench(
            &format!("gemv d_out={d_out} serial"), || {
                lin.forward_token(&x, prec, &mut sc_serial, &mut out);
                black_box(out[0]);
            });
        let ns_pooled = suite.bench(
            &format!("gemv d_out={d_out} pooled"), || {
                lin.forward_token(&x, prec, &mut sc_pool, &mut out);
                black_box(out[0]);
            });
        suite.row(&format!("gemv d_out={d_out} summary"), &[
            ("ns_serial", ns_serial),
            ("ns_pooled", ns_pooled),
            ("pooled_speedup", ns_serial / ns_pooled),
            ("gated_parallel",
             (d_out >= PARALLEL_MIN_DOUT) as u64 as f64),
        ]);
    }

    // -- 3. attention gate sweep (decode shape, ctx bracketing) --------
    suite.note(&format!(
        "ATTN_PARALLEL_MIN_WORK = {ATTN_PARALLEL_MIN_WORK}"));
    let (n_heads, n_kv, hd) = (8usize, 2usize, 64usize);
    let d = n_heads * hd;
    for &ctx in &[128usize, 256, 512, 1024, 2048] {
        let cfg = attn_cfg(n_heads, n_kv, hd, ctx);
        let mut cache = KvCache::new(ctx, n_kv, hd);
        for _ in 0..ctx {
            let k = rng.normal_vec(n_kv * hd, 1.0);
            let v = rng.normal_vec(n_kv * hd, 1.0);
            cache.push(&k, &v);
        }
        let q = rng.normal_vec(d, 1.0);
        let mut out = vec![0f32; d];
        let mut sc = AttnScratch::new();
        let ns_serial = suite.bench(
            &format!("attn decode ctx={ctx} serial"), || {
                attention_block(&cfg, &q, &cache, ctx - 1, 1, &mut sc,
                                None, &mut out);
                black_box(out[0]);
            });
        let ns_pooled = suite.bench(
            &format!("attn decode ctx={ctx} pooled"), || {
                attention_block(&cfg, &q, &cache, ctx - 1, 1, &mut sc,
                                Some(&pool), &mut out);
                black_box(out[0]);
            });
        suite.row(&format!("attn decode ctx={ctx} summary"), &[
            ("ns_serial", ns_serial),
            ("ns_pooled", ns_pooled),
            ("pooled_speedup", ns_serial / ns_pooled),
            ("gated_parallel",
             (ctx * hd >= ATTN_PARALLEL_MIN_WORK) as u64 as f64),
        ]);
    }

    suite.note("targets: persistent dispatch >= 10x cheaper than \
                scoped spawns at the empty/small-grain points; gemv \
                and attention pooled rows ~equal serial below their \
                gates (fallback) and scaling toward the worker count \
                above them (EXPERIMENTS.md §Runtime)");
    suite.finish();
}
