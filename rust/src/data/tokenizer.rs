//! Byte-level tokenizer (vocab = 256), mirroring
//! python/compile/corpus.py::tokenize, plus a small greedy-BPE trainer
//! used by the workload generator to build prompt vocabularies.

/// Byte-level encode: identity over UTF-8 bytes.
pub fn encode(text: &str) -> Vec<u32> {
    text.as_bytes().iter().map(|&b| b as u32).collect()
}

/// Decode byte tokens back to a (lossy) string.
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| t as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// A learned merge rule (a, b) -> new_id.
#[derive(Debug, Clone, PartialEq)]
pub struct Merge {
    pub a: u32,
    pub b: u32,
    pub id: u32,
}

/// Greedy byte-pair-encoding trainer.  Not used by the model itself (the
/// substitute family is byte-level like the paper's smallest settings),
/// but the workload generator uses merges to sample realistic prompt
/// boundaries, and it exercises the data substrate end to end.
pub struct Bpe {
    pub merges: Vec<Merge>,
}

impl Bpe {
    pub fn train(text: &str, n_merges: usize) -> Bpe {
        let mut toks = encode(text);
        let mut merges = Vec::new();
        let mut next_id = 256u32;
        for _ in 0..n_merges {
            let mut counts = std::collections::HashMap::new();
            for w in toks.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0usize) += 1;
            }
            let Some((&(a, b), &n)) =
                counts.iter().max_by_key(|(_, &n)| n)
            else { break };
            if n < 2 {
                break;
            }
            merges.push(Merge { a, b, id: next_id });
            toks = apply_merge(&toks, a, b, next_id);
            next_id += 1;
        }
        Bpe { merges }
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut toks = encode(text);
        for m in &self.merges {
            toks = apply_merge(&toks, m.a, m.b, m.id);
        }
        toks
    }

    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }
}

fn apply_merge(toks: &[u32], a: u32, b: u32, id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0;
    while i < toks.len() {
        if i + 1 < toks.len() && toks[i] == a && toks[i + 1] == b {
            out.push(id);
            i += 2;
        } else {
            out.push(toks[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let s = "hello, wörld!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn bpe_learns_frequent_pair() {
        let bpe = Bpe::train("ababababab", 1);
        assert_eq!(bpe.merges.len(), 1);
        let m = &bpe.merges[0];
        assert_eq!((m.a, m.b), (b'a' as u32, b'b' as u32));
        let enc = bpe.encode("abab");
        assert_eq!(enc, vec![m.id, m.id]);
    }

    #[test]
    fn bpe_stops_without_repeats() {
        let bpe = Bpe::train("abcdefg", 10);
        assert!(bpe.merges.is_empty());
    }

    #[test]
    fn merge_does_not_chain_overlap() {
        // "aaa" with merge (a,a): greedy left-to-right -> [id, a]
        let toks = apply_merge(&[97, 97, 97], 97, 97, 256);
        assert_eq!(toks, vec![256, 97]);
    }
}
