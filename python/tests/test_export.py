"""Bundle format + corpus determinism + calibration smoke."""

import os

import jax
import numpy as np
import pytest

from compile import corpus, export
from compile.config import ModelConfig, QuantConfig
from compile import model as M


def test_bundle_roundtrip(tmp_path):
    w = export.BundleWriter()
    w.meta["model"] = {"d_model": 8}
    rng = np.random.default_rng(0)
    a = rng.standard_normal((3, 5)).astype(np.float32)
    b = rng.integers(0, 255, size=(7,)).astype(np.uint8)
    c = rng.integers(0, 2 ** 60, size=(2, 2)).astype(np.uint64)
    w.add("a", a)
    w.add("b", b)
    w.add("c", c)
    path = str(tmp_path / "t.mobiq")
    w.write(path)
    man, tensors = export.read_bundle(path)
    assert man["model"]["d_model"] == 8
    np.testing.assert_array_equal(tensors["a"], a)
    np.testing.assert_array_equal(tensors["b"], b)
    np.testing.assert_array_equal(tensors["c"], c)


def test_bundle_rejects_duplicates():
    w = export.BundleWriter()
    w.add("x", np.zeros(3, np.float32))
    with pytest.raises(AssertionError):
        w.add("x", np.zeros(3, np.float32))


def test_bundle_alignment(tmp_path):
    w = export.BundleWriter()
    w.add("odd", np.zeros(3, np.uint8))      # 3 bytes -> padded to 8
    w.add("f", np.ones(2, np.float32))
    path = str(tmp_path / "t.mobiq")
    w.write(path)
    _, tensors = export.read_bundle(path)
    np.testing.assert_array_equal(tensors["f"], [1.0, 1.0])


def test_corpus_deterministic_across_calls():
    a = corpus.generate("wiki", 5000, seed=3)
    b = corpus.generate("wiki", 5000, seed=3)
    assert a == b
    c = corpus.generate("wiki", 5000, seed=4)
    assert a != c
    # domains differ
    assert corpus.generate("web", 3000) != corpus.generate("news", 3000)


def test_corpus_stable_seed_value():
    """Pin the stable-hash so Rust/Python stay in sync across processes."""
    assert corpus._stable_seed("wiki", 0) == corpus._stable_seed("wiki", 0)
    assert corpus._stable_seed("wiki", 0) != corpus._stable_seed("web", 0)


def test_tokenize_byte_range():
    t = corpus.tokenize("hé")
    assert t.dtype == np.uint8
    assert len(t) == 3  # utf-8


def test_calibration_smoke_and_export(tmp_path):
    """End-to-end micro calibration -> bundle -> read-back."""
    from compile.quant.calibrate import calibrate
    from compile.aot import build_bundle, build_static_records, \
        capture_linear_inputs

    cfg = ModelConfig(name="micro", d_model=32, n_layers=1, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=61)
    qcfg = QuantConfig(nsamples=6, seq_len=16)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(0).integers(0, 61, size=(6, 16))
    cres = calibrate(params, cfg, qcfg, toks, mode="mobiq",
                     stage1_steps=2, stage2_steps=4, minibatch=3,
                     verbose=False)
    co = calibrate(params, cfg, qcfg, toks, mode="omniquant", bits=3,
                   stage1_steps=2, stage2_steps=0, minibatch=3,
                   verbose=False)
    acts = capture_linear_inputs(params, cfg, toks[:2])
    statics = build_static_records(params, cfg, qcfg, acts, {3: co},
                                   (3,), verbose=False)
    path = str(tmp_path / "micro.mobiq")
    golden = np.arange(8, dtype=np.int32)
    build_bundle(path, params, cfg, qcfg, cres, statics,
                 {"final_loss": 0.0, "curve": [(0, 0.0)]}, golden)
    man, tensors = export.read_bundle(path)
    assert man["model"]["d_model"] == 32
    assert "mobiq.layers.0.wq.slice0.planes" in tensors
    assert "static.gptq3.layers.0.wq.codes" in tensors
    assert "golden.logits_fp" in tensors
    assert tensors["golden.logits_fp"].shape == (8, 61)
