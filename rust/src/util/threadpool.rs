//! Fixed-size thread pool with scoped parallel-for (tokio/rayon are not
//! vendored; the coordinator and the d_out-parallel kernel paths use
//! this).
//!
//! Two execution modes with different lifetime needs:
//!
//! * `execute` — fire-and-forget `'static` jobs on persistent workers
//!   fed by an mpsc channel.  Workers spawn lazily on first use, so
//!   pools that only ever run `parallel_for` (the kernel paths) never
//!   carry idle threads.
//! * `parallel_for` — the rayon-like "split an index range and join"
//!   pattern that `gemv_lut_parallel` / `gemm_lut_batch_parallel` use
//!   to chunk output channels (the CPU analogue of the paper's
//!   CUDA-stream slice overlap).  It uses `thread::scope` fork-join so
//!   the closure can borrow the caller's stack (LUTs, plane slices)
//!   without `'static` laundering, and worker panics propagate safely.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Raw mutable-pointer wrapper so fork-join workers can write disjoint
/// cells/ranges of one shared buffer (the kernel wrappers in
/// `mobiq/gemv.rs` and the attention kernel both partition an output
/// across workers this way).  Carrying it across threads is only sound
/// when every worker touches a disjoint index set — state the argument
/// at each use site.
pub struct SharedMut<T>(pub *mut T);
unsafe impl<T: Send> Send for SharedMut<T> {}
unsafe impl<T: Send> Sync for SharedMut<T> {}

struct Workers {
    tx: mpsc::Sender<Job>,
    handles: Vec<thread::JoinHandle<()>>,
}

pub struct ThreadPool {
    workers: OnceLock<Workers>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        ThreadPool { workers: OnceLock::new(), size: size.max(1) }
    }

    /// Persistent `execute` workers, spawned on first use.
    fn workers(&self) -> &Workers {
        self.workers.get_or_init(|| {
            let (tx, rx) = mpsc::channel::<Job>();
            let rx = Arc::new(Mutex::new(rx));
            let handles = (0..self.size)
                .map(|i| {
                    let rx = Arc::clone(&rx);
                    thread::Builder::new()
                        .name(format!("mobiq-worker-{}", i))
                        .spawn(move || loop {
                            let job = { rx.lock().unwrap().recv() };
                            match job {
                                Ok(job) => job(),
                                Err(_) => break,
                            }
                        })
                        .expect("spawn worker")
                })
                .collect();
            Workers { tx, handles }
        })
    }

    /// Pool sized to the machine: `cores - 1` (min 1).  One core is
    /// deliberately left free so the coordinator's scheduler thread (and
    /// the OS) are not preempted by kernel workers — a fully-subscribed
    /// pool makes tick latency spike under load for no throughput gain.
    pub fn default_for_machine() -> Self {
        ThreadPool::new(default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.workers().tx.send(Box::new(job)).expect("pool alive");
    }

    /// Partition `0..n` into at most `size` contiguous ranges and run
    /// `f(start, end)` for each, blocking until all complete.  The
    /// contiguity matters for locality-sensitive work: the attention
    /// kernel hands each worker a run of adjacent heads so GQA head
    /// groups sharing a KV slab stay on one worker's warm cache, and
    /// the kernel wrappers carve contiguous output-channel ranges.
    pub fn parallel_chunks(&self, n: usize,
                           f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        let n_chunks = self.size.min(n);
        let chunk = (n + n_chunks - 1) / n_chunks;
        self.parallel_for(n_chunks, |ci| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n);
            if lo < hi {
                f(lo, hi);
            }
        });
    }

    /// Run `f(chunk_index)` for each index in 0..n, blocking until all
    /// complete.  `f` must be Sync; indices are distributed dynamically.
    /// Uses std::thread::scope (joins on exit), so no extra
    /// synchronisation is needed beyond the work counter.
    pub fn parallel_for(&self, n: usize, f: impl Fn(usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.size == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let counter = AtomicUsize::new(0);
        thread::scope(|scope| {
            for _ in 0..self.size.min(n) {
                let counter = &counter;
                let f = &f;
                scope.spawn(move || loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

/// Worker count [`ThreadPool::default_for_machine`] uses: cores - 1,
/// min 1 (see the rationale there).  Exposed so CLI defaulting can show
/// the number without building a pool.
pub fn default_threads() -> usize {
    let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    n.saturating_sub(1).max(1)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(w) = self.workers.take() {
            drop(w.tx); // closes the channel; workers drain and exit
            for h in w.handles {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..64 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn parallel_for_covers_all() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0))
            .collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_for_empty() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_chunks_cover_exactly_once() {
        for (workers, n) in [(1usize, 5usize), (3, 7), (4, 4), (8, 3)] {
            let pool = ThreadPool::new(workers);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0))
                .collect();
            pool.parallel_chunks(n, |lo, hi| {
                assert!(lo < hi && hi <= n);
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1,
                           "workers={workers} n={n} index {i}");
            }
        }
        ThreadPool::new(2).parallel_chunks(0, |_, _| panic!("no work"));
    }

    #[test]
    fn default_leaves_a_core_free() {
        let n = default_threads();
        assert!(n >= 1);
        let cores = thread::available_parallelism()
            .map(|n| n.get()).unwrap_or(1);
        if cores > 1 {
            assert_eq!(n, cores - 1);
        }
    }

    #[test]
    fn drop_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
