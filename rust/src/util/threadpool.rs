//! Persistent fork-join runtime (tokio/rayon are not vendored; the
//! coordinator and every parallel kernel/elementwise path dispatch
//! through this).
//!
//! Earlier revisions ran `parallel_for` on `thread::scope`, spawning
//! fresh OS threads per call: tens of microseconds of fork/join that
//! forced the kernel/attention parallel gates (`PARALLEL_MIN_DOUT`,
//! `ATTN_PARALLEL_MIN_WORK`) high and left the per-token elementwise
//! stages serial.  Now the pool's long-lived workers park on a
//! condvar/epoch protocol and execute borrowed-closure range jobs
//! directly, so a fork-join dispatch costs one wake + one join
//! (single-digit microseconds) regardless of pool size.
//!
//! Two execution modes share the same workers:
//!
//! * `parallel_for` / `parallel_chunks` — the rayon-like "split an
//!   index range and join" pattern.  The caller publishes a
//!   type-erased pointer to its stack closure, participates in the
//!   range itself, and blocks on a per-job latch until every claimed
//!   index has finished — which is exactly what makes the lifetime
//!   laundering sound (see [`ForkJob`]).  Worker panics are captured
//!   per job and re-thrown at the join point on the calling thread.
//! * `execute` — fire-and-forget `'static` jobs on the same workers
//!   (queued behind any in-flight range work).  Send failures surface
//!   as a recoverable [`PoolClosed`] error instead of panicking, and
//!   job panics are captured and re-thrown when the pool drops.
//!
//! Workers spawn lazily on first use, so pools that are only ever
//! constructed (e.g. size-1 CLI runs) never carry idle threads.  A
//! pool of size N runs fork-join ranges at parallelism N: the caller
//! plus N-1 parked workers.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Raw mutable-pointer wrapper so fork-join workers can write disjoint
/// cells/ranges of one shared buffer (the kernel wrappers in
/// `mobiq/gemv.rs`, the attention kernel and the block elementwise
/// helpers all partition an output across workers this way).  Carrying
/// it across threads is only sound when every worker touches a disjoint
/// index set — state the argument at each use site.
pub struct SharedMut<T>(pub *mut T);
unsafe impl<T: Send> Send for SharedMut<T> {}
unsafe impl<T: Send> Sync for SharedMut<T> {}

/// Error returned by [`ThreadPool::execute`] when the pool has begun
/// shutting down and can no longer accept jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolClosed;

impl std::fmt::Display for PoolClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "thread pool is shut down")
    }
}

impl std::error::Error for PoolClosed {}

/// One published fork-join range job.
///
/// `func` is a type-erased pointer to a closure borrowed from the
/// *caller's stack*.  The lifetime laundering is sound because of the
/// claim/latch protocol:
///
/// * every index of `0..n` must be claimed (via `next`) before
///   `remaining` can reach 0, and `remaining` is only decremented
///   after the claimed index's call returns (or panics);
/// * the caller blocks on the `done` latch until `remaining == 0`, so
///   the closure cannot be executing on any thread once `parallel_for`
///   returns;
/// * a worker that still holds an `Arc<ForkJob>` *after* the caller
///   returned can only observe `next >= n` — it never dereferences
///   the (now dangling) `func` pointer again.
struct ForkJob {
    func: *const (dyn Fn(usize) + Sync),
    n: usize,
    /// Next unclaimed index (dynamic distribution).
    next: AtomicUsize,
    /// Indices claimed-and-finished still outstanding; the job is
    /// complete when this hits 0.  AcqRel so one worker's writes are
    /// visible to whichever thread observes the final decrement.
    remaining: AtomicUsize,
    /// First panic captured from any index (re-thrown at the join).
    panic: Mutex<Option<PanicPayload>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `func` is only dereferenced under the claim protocol above,
// and the closure it points to is `Sync` (shared-called from many
// threads) — the raw pointer itself is what prevents the auto-impls.
unsafe impl Send for ForkJob {}
unsafe impl Sync for ForkJob {}

impl ForkJob {
    /// Claim and run range indices until the range is exhausted.
    /// Called by the publishing thread and by any worker that woke for
    /// this job's epoch.
    fn run(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            // SAFETY: i < n, so the caller is still latched and the
            // borrowed closure is alive (see the struct invariant).
            let f = unsafe { &*self.func };
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = self.panic.lock().unwrap();
                slot.get_or_insert(p);
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                *self.done.lock().unwrap() = true;
                self.done_cv.notify_all();
            }
        }
    }
}

/// Shared worker-visible state, guarded by one mutex: the fire-and-
/// forget queue, the current fork-job slot + epoch, and shutdown.
struct PoolState {
    queue: VecDeque<Job>,
    /// Bumped once per published fork job; workers compare against a
    /// thread-local copy so a job is joined at most once per worker.
    epoch: u64,
    fork: Option<Arc<ForkJob>>,
    shutdown: bool,
    /// First panic captured from a fire-and-forget `execute` job
    /// (re-thrown when the pool drops; range-job panics re-throw at
    /// their join point instead).
    exec_panic: Option<PanicPayload>,
}

struct Inner {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct Workers {
    inner: Arc<Inner>,
    handles: Vec<thread::JoinHandle<()>>,
}

pub struct ThreadPool {
    workers: OnceLock<Workers>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        ThreadPool { workers: OnceLock::new(), size: size.max(1) }
    }

    /// Persistent parked workers, spawned on first use.  A size-N pool
    /// keeps N-1 workers (the fork-join caller is the N-th lane); a
    /// size-1 pool still gets one worker so `execute` jobs have
    /// somewhere to run (its fork-join path is inline/serial).
    fn workers(&self) -> &Workers {
        self.workers.get_or_init(|| {
            let inner = Arc::new(Inner {
                state: Mutex::new(PoolState {
                    queue: VecDeque::new(),
                    epoch: 0,
                    fork: None,
                    shutdown: false,
                    exec_panic: None,
                }),
                work_cv: Condvar::new(),
            });
            let n_workers = self.size.saturating_sub(1).max(1);
            let handles = (0..n_workers)
                .map(|i| {
                    let inner = Arc::clone(&inner);
                    thread::Builder::new()
                        .name(format!("mobiq-worker-{}", i))
                        .spawn(move || worker_loop(&inner))
                        .expect("spawn worker")
                })
                .collect();
            Workers { inner, handles }
        })
    }

    /// Eagerly spawn the persistent workers (normally lazy).  The
    /// coordinator calls this at server start so the first tick does
    /// not pay thread creation inside a latency-sensitive dispatch.
    pub fn warm(&self) {
        if self.size > 1 {
            self.workers();
        }
    }

    /// Pool sized to the machine: `cores - 1` (min 1).  One core is
    /// deliberately left free so the coordinator's scheduler thread (and
    /// the OS) are not preempted by kernel workers — a fully-subscribed
    /// pool makes tick latency spike under load for no throughput gain.
    /// (The fork-join caller counts as one of the `size` lanes, so a
    /// dispatch never runs more than `size` bodies concurrently.)
    pub fn default_for_machine() -> Self {
        ThreadPool::new(default_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Queue a fire-and-forget `'static` job on the persistent workers.
    /// Jobs run behind any in-flight fork-join range work.  Returns
    /// [`PoolClosed`] (instead of panicking) if the pool is shutting
    /// down; a panicking job is captured and re-thrown when the pool
    /// drops, and never kills its worker.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static)
                   -> Result<(), PoolClosed> {
        let w = self.workers();
        let mut st = w.inner.state.lock().unwrap();
        if st.shutdown {
            return Err(PoolClosed);
        }
        st.queue.push_back(Box::new(job));
        drop(st);
        w.inner.work_cv.notify_one();
        Ok(())
    }

    /// Partition `0..n` into at most `size` contiguous ranges and run
    /// `f(start, end)` for each, blocking until all complete.  The
    /// contiguity matters for locality-sensitive work: the attention
    /// kernel hands each worker a run of adjacent heads so GQA head
    /// groups sharing a KV slab stay on one worker's warm cache, and
    /// the kernel wrappers carve contiguous output-channel ranges.
    pub fn parallel_chunks(&self, n: usize,
                           f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        let n_chunks = self.size.min(n);
        let chunk = (n + n_chunks - 1) / n_chunks;
        self.parallel_for(n_chunks, |ci| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n);
            if lo < hi {
                f(lo, hi);
            }
        });
    }

    /// Run `f(i)` for each index in 0..n, blocking until all complete.
    /// `f` must be Sync; indices are distributed dynamically.  The
    /// calling thread participates in the range (so a size-N pool runs
    /// at parallelism N: caller + N-1 parked workers), then blocks on
    /// the job's latch; a panic in any body is re-thrown here after the
    /// join, with the workers surviving.
    ///
    /// Concurrent `parallel_for` calls from different threads are safe:
    /// the later publication wins the fork slot and the earlier job is
    /// simply completed by its own caller (each job's completion is
    /// tracked independently).  A nested call from inside a body is
    /// likewise safe and degrades to (mostly) inline execution, since
    /// busy workers only look for new jobs between range items.
    pub fn parallel_for(&self, n: usize, f: impl Fn(usize) + Sync) {
        if n == 0 {
            return;
        }
        if self.size == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let w = self.workers();
        // Type-erase and lifetime-launder the borrowed closure.
        // SAFETY: the ForkJob claim/latch protocol guarantees no thread
        // dereferences `func` after this frame returns (see ForkJob).
        let fref: &(dyn Fn(usize) + Sync) = &f;
        let func: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(fref) };
        let job = Arc::new(ForkJob {
            func,
            n,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        {
            let mut st = w.inner.state.lock().unwrap();
            st.epoch = st.epoch.wrapping_add(1);
            st.fork = Some(Arc::clone(&job));
            drop(st);
            // Targeted wakeup: a range of n items occupies at most
            // min(n, size) lanes and the caller is one of them, so at
            // most min(n, size) - 1 parked workers can contribute.
            // Waking every worker (`notify_all`) just paid wakeup +
            // re-park latency on threads that would find the range
            // drained — measurable on small dispatches, which are the
            // common case now that the elementwise gates sit low.
            // Busy workers that miss the notification still join via
            // the epoch check when they next take the lock, and extra
            // notifies against an empty wait queue are no-ops, so no
            // wakeup is ever lost.
            let wake = self.size.min(n) - 1;
            for _ in 0..wake {
                w.inner.work_cv.notify_one();
            }
        }
        // The caller is one of the lanes.
        job.run();
        // Join barrier: wait until every claimed index has finished.
        {
            let mut done = job.done.lock().unwrap();
            while !*done {
                done = job.done_cv.wait(done).unwrap();
            }
        }
        // Hygiene: drop the state's reference to the (now-complete)
        // job so its dangling closure pointer does not outlive this
        // call inside the pool.  A racing later publication may have
        // replaced the slot already — only clear our own job.
        {
            let mut st = w.inner.state.lock().unwrap();
            if st.fork.as_ref().is_some_and(|j| Arc::ptr_eq(j, &job)) {
                st.fork = None;
            }
        }
        let payload = job.panic.lock().unwrap().take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

/// Body of each persistent worker: join any newly published fork-job
/// epoch first (range work is the latency-critical hot path), then
/// drain fire-and-forget jobs, otherwise park on the condvar.
fn worker_loop(inner: &Inner) {
    let mut seen_epoch = 0u64;
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.epoch != seen_epoch {
            seen_epoch = st.epoch;
            if let Some(job) = st.fork.clone() {
                drop(st);
                job.run();
                st = inner.state.lock().unwrap();
            }
            continue;
        }
        if let Some(job) = st.queue.pop_front() {
            drop(st);
            if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
                let mut st2 = inner.state.lock().unwrap();
                st2.exec_panic.get_or_insert(p);
            }
            st = inner.state.lock().unwrap();
            continue;
        }
        if st.shutdown {
            break;
        }
        st = inner.work_cv.wait(st).unwrap();
    }
}

/// Worker count [`ThreadPool::default_for_machine`] uses: cores - 1,
/// min 1 (see the rationale there).  Exposed so CLI defaulting can show
/// the number without building a pool.
pub fn default_threads() -> usize {
    let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    n.saturating_sub(1).max(1)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        let Some(w) = self.workers.take() else { return };
        {
            let mut st = w.inner.state.lock().unwrap();
            st.shutdown = true;
            drop(st);
            w.inner.work_cv.notify_all();
        }
        let mut worker_panic: Option<PanicPayload> = None;
        for h in w.handles {
            if let Err(p) = h.join() {
                worker_panic.get_or_insert(p);
            }
        }
        let exec_panic = w.inner.state.lock().unwrap().exec_panic.take();
        // Propagate instead of swallowing: a worker that died outside
        // the catch (should be impossible) outranks a captured job
        // panic.  Never double-panic if we are already unwinding.
        if thread::panicking() {
            return;
        }
        if let Some(p) = worker_panic.or(exec_panic) {
            resume_unwind(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::mpsc;

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            }).unwrap();
        }
        for _ in 0..64 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn parallel_for_covers_all() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0))
            .collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_for_reuses_workers_across_calls() {
        // many successive dispatches on one pool: every range covered
        // exactly once each time (epoch protocol, no stale joins)
        let pool = ThreadPool::new(4);
        for round in 0..200usize {
            let n = 1 + (round % 17);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0))
                .collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1,
                           "round {round} index {i}");
            }
        }
    }

    /// Targeted wakeups: small ranges on a big pool must still cover
    /// every index, across many rounds and interleaved with full-width
    /// ranges (a worker that missed a wakeup joins via the epoch check
    /// on its next lock, so nothing is lost).
    #[test]
    fn targeted_wakeup_small_ranges_on_big_pool() {
        let pool = ThreadPool::new(8);
        pool.warm();
        for round in 0..200usize {
            let n = if round % 5 == 0 { 16 } else { 2 };
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0))
                .collect();
            pool.parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1,
                           "round {round} index {i}");
            }
        }
    }

    #[test]
    fn parallel_for_empty() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_chunks_cover_exactly_once() {
        for (workers, n) in [(1usize, 5usize), (3, 7), (4, 4), (8, 3)] {
            let pool = ThreadPool::new(workers);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0))
                .collect();
            pool.parallel_chunks(n, |lo, hi| {
                assert!(lo < hi && hi <= n);
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1,
                           "workers={workers} n={n} index {i}");
            }
        }
        ThreadPool::new(2).parallel_chunks(0, |_, _| panic!("no work"));
    }

    #[test]
    fn panic_propagates_at_join_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(32, |i| {
                if i == 7 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(r.is_err(), "body panic must re-throw at the join");
        // workers survived the panic: the pool still dispatches
        let hits: Vec<AtomicU64> = (0..16).map(|_| AtomicU64::new(0))
            .collect();
        pool.parallel_for(16, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
        let (tx, rx) = mpsc::channel();
        pool.execute(move || tx.send(()).unwrap()).unwrap();
        rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    }

    #[test]
    fn reentrant_execute_from_parallel_body() {
        // a range body may queue fire-and-forget jobs on the same pool
        // without deadlocking (the body holds no pool locks)
        let pool = Arc::new(ThreadPool::new(3));
        let (tx, rx) = mpsc::channel::<usize>();
        {
            let pool2 = Arc::clone(&pool);
            // Sender is Send but not Sync on all supported toolchains;
            // park it behind a Mutex so the Fn + Sync body can clone it
            let tx = Mutex::new(tx.clone());
            pool.parallel_for(8, move |i| {
                let tx = tx.lock().unwrap().clone();
                pool2.execute(move || tx.send(i).unwrap()).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<usize> = (0..8)
            .map(|_| rx.recv_timeout(std::time::Duration::from_secs(5))
                .unwrap())
            .collect();
        got.sort();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn nested_parallel_for_completes() {
        // an inner dispatch from inside a body must not deadlock; it
        // degrades toward inline execution while workers are busy
        let pool = ThreadPool::new(3);
        let total = AtomicU64::new(0);
        pool.parallel_for(4, |_| {
            pool.parallel_for(4, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn execute_job_panic_propagates_on_drop() {
        let r = std::panic::catch_unwind(|| {
            let pool = ThreadPool::new(2);
            let (tx, rx) = mpsc::channel();
            pool.execute(move || {
                tx.send(()).unwrap();
                panic!("exec boom");
            }).unwrap();
            // make sure the job ran before the drop
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
            drop(pool);
        });
        assert!(r.is_err(),
                "captured execute-job panic must re-throw at Drop");
    }

    #[test]
    fn default_leaves_a_core_free() {
        let n = default_threads();
        assert!(n >= 1);
        let cores = thread::available_parallelism()
            .map(|n| n.get()).unwrap_or(1);
        if cores > 1 {
            assert_eq!(n, cores - 1);
        }
    }

    #[test]
    fn drop_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {}).unwrap();
        drop(pool); // must not hang
    }

    #[test]
    fn warm_then_dispatch() {
        let pool = ThreadPool::new(3);
        pool.warm();
        let hits: Vec<AtomicU64> = (0..10).map(|_| AtomicU64::new(0))
            .collect();
        pool.parallel_for(10, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }
}
