"""MoBiQuant calibration stack (build-time).

Modules:
  quantizer    — floor-aligned group quantizer (paper Eq. 11-12)
  mobislice    — recursive residual bit-slice decomposition (Eq. 2-3, App. B)
  router       — MoBiRoute MLP, annealed gating, budget regularisation
  schedules    — temperature / budget schedules (App. D.2)
  calibrate    — Alg. 1 layer-wise joint optimisation (OmniQuant-lite + MoBi)
  gptq         — GPTQ baseline (Hessian-based column updates)
  awq          — AWQ baseline (activation-aware scale search)
  smoothquant  — SmoothQuant baseline (outlier migration into weights)
  rotation     — QuaRot-lite / SpinQuant-lite Hadamard rotations
"""

from . import quantizer, mobislice, router, schedules  # noqa: F401
