//! Fig. 6 — (left) block-level average precision assignments; (right)
//! per-token precision distribution under different target budgets.

use mobiquant::bench_support as bs;
use mobiquant::data::ppl;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::{BackendKind, LINEAR_NAMES};
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("fig6_assignments");
    suite.header();
    let windows = bs::eval_windows(4);
    let Ok(toks) = bs::valid_tokens("wiki") else {
        suite.note("no corpus");
        suite.finish();
        return;
    };

    for mname in bs::models_available().iter().take(2) {
        let Some(bundle) = bs::try_bundle(mname) else { continue };
        let model = Model::load(&bundle, BackendKind::Mobiq).unwrap();

        for target in [3.0, 4.0, 5.0] {
            // drive decode while collecting routing stats
            let mut stats = DecodeStats::new(model.cfg.n_layers);
            let (mut arena, seq) = model.new_kv();
            let mut scratch = model.new_scratch();
            for i in 0..windows {
                arena.reset_seq(seq);
                for &t in &toks[i * 128..(i + 1) * 128] {
                    model.decode_step(t, &mut arena, seq,
                                      Precision::elastic(target),
                                      &mut scratch, &mut stats).unwrap();
                }
            }
            // right panel: token bit histogram (k = active slices)
            let total: u64 = stats.bits_hist.iter().sum();
            let hist: Vec<(String, f64)> = (1..=model.cfg.n_slices)
                .map(|k| (format!("{}bit", 2 * k),
                          stats.bits_hist[k] as f64 / total as f64))
                .collect();
            let named: Vec<(&str, f64)> = hist.iter()
                .map(|(k, v)| (k.as_str(), *v)).collect();
            suite.row(&format!("{mname} target{target} token dist"),
                      &named);
            suite.row(&format!("{mname} target{target} avg bits"),
                      &[("avg", stats.avg_bits())]);

            // left panel: block-level averages
            for (li, _) in model.layers.iter().enumerate() {
                let cells: Vec<(String, f64)> = LINEAR_NAMES.iter()
                    .enumerate()
                    .map(|(ni, n)| (n.to_string(),
                                    stats.block_avg_bits(li, ni)))
                    .collect();
                let named: Vec<(&str, f64)> = cells.iter()
                    .map(|(k, v)| (k.as_str(), *v)).collect();
                suite.row(&format!("{mname} t{target} layer{li} bits"),
                          &named);
            }
        }

        // sanity: realized avg tracks budget in PPL eval too
        let r = ppl::evaluate(&model, &toks, Precision::elastic(3.0), 128,
                              windows).unwrap();
        suite.row(&format!("{mname} ppl@target3"),
                  &[("ppl", r.ppl), ("avg_bits", r.avg_bits)]);
    }
    suite.note("paper shape: heterogeneous token assignment shifting with \
                budget; block-level variation across layers/linears");
    suite.finish();
}
