//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>,
                 known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), &["verbose"])
    }

    #[test]
    fn parses_forms() {
        let a = mk(&["serve", "--port", "8080", "--mode=elastic",
                     "--verbose", "extra"]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("elastic"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("port", 0), 8080);
    }

    #[test]
    fn unknown_trailing_flag() {
        let a = mk(&["--dry-run"]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn defaults() {
        let a = mk(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
    }
}
