//! Blocked, head-parallel online-softmax attention + cached RoPE.
//!
//! The second half of the forward pass after PR 1 made the linears
//! batched: `attention_step` (kept below as the scalar oracle) was a
//! head-serial, one-position-at-a-time kernel that the prefill loop
//! called T times per layer, plus a RoPE helper recomputing
//! `theta.powf` and `sin_cos` per (head, pair, position).  This module
//! replaces both on the hot path:
//!
//! * [`RopeCache`] — per-pair inverse frequencies computed once, sin/cos
//!   rows cached per position and grown on demand, so the token loop
//!   runs zero transcendentals.
//! * [`append_kv_block`] — lands a block of fresh K/V rows in the
//!   head-major cache slabs (`kvcache.rs`) in one pass, fusing the
//!   K-side RoPE rotation into the scatter (no staging copy through
//!   per-position `push` calls).
//! * [`attention_block`] — all of a block's queries against the cache in
//!   position tiles with single-pass online softmax (flash-style running
//!   max/denominator, no full score buffer per query), parallelised over
//!   contiguous head chunks on the shared [`ThreadPool`].  Each K/V tile
//!   is streamed from the head-major slab once and reused by every query
//!   whose causal range covers it.
//! * [`attention_cross_slots`] — the coalesced decode tick's attention:
//!   every slot's single query in one fork-join dispatch over the
//!   flattened `slot x head` grid (same per-head kernel, so cross-slot
//!   results are bit-identical to the per-slot loop it replaces).
//!
//! Since PR 5 the kernels also consume **quantized KV pages**
//! (`kvcache::KvPrecision`): a tile's `k_run`/`v_run` may come back as
//! int8 or packed int4 codes plus one absmax scale, and dequantization
//! is fused into the dot product / weighted accumulate with the scale
//! hoisted out of the `head_dim` inner loop (tiles never straddle a
//! page, so the scale is uniform per tile).  The f32 paths are
//! untouched — bit-identical to the pre-quantization kernel.
//!
//! Since PR 9 the quantized dot / weighted-accumulate inner loops go
//! through [`crate::util::simd`]: with `MOBIQ_SIMD=off` they dispatch
//! to the exact pre-PR sequential loops; when enabled they follow the
//! lane-blocked fixed-reduction-order contract, so the wide kernels
//! are bit-identical to their blocked scalar references and both arms
//! stay inside the existing quantized oracle bounds
//! (`tests/kv_arena.rs`, `tests/simd_parity.rs`).
//!
//! Determinism note: position tiles are anchored at absolute position 0
//! (`[0, TILE)`, `[TILE, 2*TILE)`, ...), independent of where a block
//! starts.  A query at absolute position P therefore accumulates its
//! softmax in the same order whether it arrives via single-token decode
//! (t = 1) or inside a prefill block — the two paths stay bit-identical
//! to each other.  Against the scalar oracle the result differs only by
//! FP reordering (the parity tests use a 1e-4 tolerance).

use super::kvcache::{KvCache, KvRun, KvSource, KV_PAGE};
use super::weights::ModelConfig;
use crate::util::simd;
use crate::util::threadpool::{SharedMut, ThreadPool};
use crate::util::tunable::TunableGate;

/// Key/value positions per tile.  32 positions x head_dim 64 x 4 B =
/// 8 KB of K plus 8 KB of V per tile — comfortably L1-resident while a
/// whole query block (<= MAX_PREFILL_BLOCK) reuses it.
pub const ATTN_TILE: usize = 32;

// Tiles are anchored at absolute multiples of ATTN_TILE, so this is
// what guarantees a tile never straddles a KV page: every `k_run`/
// `v_run` the kernel requests resolves to one contiguous span whether
// the source is a slab or a paged arena view.  For quantized pages it
// also guarantees the run's absmax scale is uniform over the tile —
// the dequant multiply hoists out of the inner loop (one multiply per
// position, none per element).
const _: () = assert!(KV_PAGE % ATTN_TILE == 0,
                      "KV pages must hold whole attention tiles");

/// Minimum `(query, key) pair x head_dim` volume before the fork-join
/// dispatch of `parallel_chunks` is worth paying.  Re-derived for the
/// persistent pool (EXPERIMENTS.md §Runtime): a dispatch costs a
/// condvar wake + join (~2 µs, was tens of µs of scoped spawns), so
/// the gate dropped 8x from `1 << 17`.  Prefill blocks now clear it
/// from ctx ~16 up, and single-query decode goes head-parallel from
/// ctx >= 256 at head_dim 64 (was >= 2048) — which is also what lets
/// the cross-slot decode dispatch engage at serving batch sizes.
pub const ATTN_PARALLEL_MIN_WORK: usize = 1 << 14;

/// Runtime-overridable view of [`ATTN_PARALLEL_MIN_WORK`]:
/// `MOBIQ_ATTN_PARALLEL_MIN_WORK` or `ServerConfig.attn_parallel_min_work`
/// moves the dispatch threshold without a rebuild (tuning knob for the
/// first cargo-equipped session).  Dispatch only — per-head math is
/// identical either way.
pub static ATTN_PARALLEL_MIN_WORK_GATE: TunableGate =
    TunableGate::new("MOBIQ_ATTN_PARALLEL_MIN_WORK",
                     ATTN_PARALLEL_MIN_WORK);

// ---------------------------------------------------------------------------
// RoPE cache
// ---------------------------------------------------------------------------

/// Cached interleaved-pair RoPE tables: inverse frequencies are
/// position-invariant (computed once per model shape), sin/cos rows are
/// head-invariant (cached per position, grown on demand).
pub struct RopeCache {
    head_dim: usize,
    half: usize,
    inv_freq: Vec<f32>,
    /// `(positions, half)` row-major tables.
    cos: Vec<f32>,
    sin: Vec<f32>,
    positions: usize,
}

impl RopeCache {
    pub fn new(head_dim: usize, theta: f32) -> RopeCache {
        let half = head_dim / 2;
        let inv_freq = (0..half)
            .map(|i| 1.0 / theta.powf(i as f32 / half as f32))
            .collect();
        RopeCache {
            head_dim,
            half,
            inv_freq,
            cos: Vec::new(),
            sin: Vec::new(),
            positions: 0,
        }
    }

    /// Grow the sin/cos tables to cover positions `0..n`.
    pub fn ensure(&mut self, n: usize) {
        if self.positions >= n {
            return;
        }
        self.cos.reserve((n - self.positions) * self.half);
        self.sin.reserve((n - self.positions) * self.half);
        for pos in self.positions..n {
            for &f in &self.inv_freq {
                let (s, c) = (pos as f32 * f).sin_cos();
                self.cos.push(c);
                self.sin.push(s);
            }
        }
        self.positions = n;
    }

    /// Number of positions currently tabled.
    pub fn positions(&self) -> usize {
        self.positions
    }

    /// (cos, sin) rows for one position (must be `ensure`d).
    #[inline]
    pub fn row(&self, pos: usize) -> (&[f32], &[f32]) {
        let lo = pos * self.half;
        (&self.cos[lo..lo + self.half], &self.sin[lo..lo + self.half])
    }

    /// Rotate all heads of one `(n_heads * head_dim)` row in place —
    /// same math as the scalar [`rope`] reference, minus the
    /// transcendentals (the tables hold identical `powf`/`sin_cos`
    /// results, so outputs are bit-identical).
    pub fn apply(&self, v: &mut [f32], pos: usize) {
        let (cos, sin) = self.row(pos);
        for head in v.chunks_exact_mut(self.head_dim) {
            for i in 0..self.half {
                let (c, s) = (cos[i], sin[i]);
                let a = head[2 * i];
                let b = head[2 * i + 1];
                head[2 * i] = a * c - b * s;
                head[2 * i + 1] = a * s + b * c;
            }
        }
    }
}

/// Interleaved-pair RoPE over heads laid out contiguously in `v` — the
/// uncached scalar reference ([`RopeCache`] is pinned to it by test).
pub fn rope(v: &mut [f32], pos: usize, head_dim: usize, theta: f32) {
    let half = head_dim / 2;
    let n_heads = v.len() / head_dim;
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..half {
            let freq = 1.0 / theta.powf(i as f32 / half as f32);
            let ang = pos as f32 * freq;
            let (s, c) = ang.sin_cos();
            let a = v[base + 2 * i];
            let b = v[base + 2 * i + 1];
            v[base + 2 * i] = a * c - b * s;
            v[base + 2 * i + 1] = a * s + b * c;
        }
    }
}

// ---------------------------------------------------------------------------
// KV block append (fused RoPE + head-major scatter)
// ---------------------------------------------------------------------------

/// Write a `(t, n_kv_heads * head_dim)` row-major K/V block (fresh
/// linear outputs) into `cache`'s head-major slabs, applying RoPE to
/// the K rows from the cached tables while scattering.  One read of the
/// block, one write of the slab — replaces the per-position
/// `push` + in-place `rope` pair.  Returns the first appended position;
/// the caller must have `rope.ensure(pos0 + t)`d.
pub fn append_kv_block(cache: &mut KvCache, rope: &RopeCache,
                       k_block: &[f32], v_block: &[f32],
                       t: usize) -> usize {
    let hd = cache.head_dim;
    let half = hd / 2;
    let w = cache.width();
    debug_assert!(k_block.len() >= t * w && v_block.len() >= t * w);
    let pos0 = cache.reserve(t);
    for h in 0..cache.n_kv_heads {
        for i in 0..t {
            let (cos, sin) = rope.row(pos0 + i);
            let src = &k_block[i * w + h * hd..][..hd];
            let dst = cache.k_head_row_mut(h, pos0 + i);
            for j in 0..half {
                let (a, b) = (src[2 * j], src[2 * j + 1]);
                dst[2 * j] = a * cos[j] - b * sin[j];
                dst[2 * j + 1] = a * sin[j] + b * cos[j];
            }
        }
        for i in 0..t {
            let src = &v_block[i * w + h * hd..][..hd];
            cache.v_head_row_mut(h, pos0 + i).copy_from_slice(src);
        }
    }
    pos0
}

// ---------------------------------------------------------------------------
// Tiled online-softmax kernel
// ---------------------------------------------------------------------------

/// Per-head online-softmax state, pre-sized so the hot loop never
/// allocates.  One per head (heads are the parallel work unit, so each
/// worker touches a disjoint set of these).
#[derive(Default)]
struct HeadScratch {
    /// Running max per query row.
    m: Vec<f32>,
    /// Running softmax denominator per query row.
    l: Vec<f32>,
    /// Unnormalised context accumulator, `(t, head_dim)`.
    acc: Vec<f32>,
    /// Current tile's scores.
    s: Vec<f32>,
}

impl HeadScratch {
    fn ensure(&mut self, t: usize, hd: usize) {
        if self.m.len() < t {
            self.m.resize(t, 0.0);
            self.l.resize(t, 0.0);
        }
        if self.acc.len() < t * hd {
            self.acc.resize(t * hd, 0.0);
        }
        if self.s.len() < ATTN_TILE {
            self.s.resize(ATTN_TILE, 0.0);
        }
    }
}

/// Grow-only scratch for [`attention_block`]; lives in `DecodeScratch`.
#[derive(Default)]
pub struct AttnScratch {
    heads: Vec<HeadScratch>,
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch::default()
    }

    fn ensure(&mut self, n_heads: usize, t: usize, hd: usize) {
        while self.heads.len() < n_heads {
            self.heads.push(HeadScratch::default());
        }
        for hs in &mut self.heads[..n_heads] {
            hs.ensure(t, hd);
        }
    }
}

/// Shared output pointer for the parallel workers (see
/// `util::threadpool::SharedMut`): every head is owned by exactly one
/// worker, and a head only ever materialises `&mut` over its own
/// `head_dim` span of each ctx row.
type SharedCtx = SharedMut<f32>;

/// Same for the per-head scratch array: worker chunks own disjoint
/// head index ranges.
type SharedHeads = SharedMut<HeadScratch>;

/// Causal attention of a whole block of queries against the cache.
///
/// * `q` — `(t, n_heads * head_dim)` row-major, RoPE already applied;
///   query row `i` sits at absolute position `pos0 + i`.
/// * `cache` — any [`KvSource`] (slab cache or paged arena view) for
///   this layer, already holding the block's own K/V (append first),
///   i.e. `cache.len() >= pos0 + t`.  Causality is enforced by
///   masking: query `i` only consumes positions `0..=pos0 + i`.
/// * `ctx` — `(t, n_heads * head_dim)` output.
///
/// Work is split over contiguous head chunks (heads sharing a GQA kv
/// head are adjacent, so a chunk re-reads each K/V slab from warm
/// cache) when `pool` is present and the block is big enough.
#[allow(clippy::too_many_arguments)]
pub fn attention_block<S: KvSource>(cfg: &ModelConfig, q: &[f32],
                                    cache: &S, pos0: usize, t: usize,
                                    scratch: &mut AttnScratch,
                                    pool: Option<&ThreadPool>,
                                    ctx: &mut [f32]) {
    if t == 0 {
        return;
    }
    let hd = cfg.head_dim();
    let n_heads = cfg.n_heads;
    let rep = n_heads / cfg.n_kv_heads;
    let d = n_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert!(q.len() >= t * d && ctx.len() >= t * d);
    debug_assert!(cache.len() >= pos0 + t, "block K/V not in cache yet");
    scratch.ensure(n_heads, t, hd);

    let work = t * (pos0 + t) * hd;
    let parallel = n_heads > 1
        && work >= ATTN_PARALLEL_MIN_WORK_GATE.get()
        && pool.is_some_and(|p| p.size() > 1);
    let cptr = SharedCtx(ctx.as_mut_ptr());
    if !parallel {
        for (h, hs) in scratch.heads[..n_heads].iter_mut().enumerate() {
            attn_head(q, d, h * hd, cache, h / rep, hd, d, h * hd,
                      scale, pos0, t, hs, &cptr);
        }
        return;
    }
    let hptr = SharedHeads(scratch.heads.as_mut_ptr());
    pool.unwrap().parallel_chunks(n_heads, |h0, h1| {
        for h in h0..h1 {
            // SAFETY: parallel_chunks hands out disjoint head ranges,
            // so this worker is the only one touching heads[h] and the
            // h-th ctx spans.
            let hs = unsafe { &mut *hptr.0.add(h) };
            attn_head(q, d, h * hd, cache, h / rep, hd, d, h * hd,
                      scale, pos0, t, hs, &cptr);
        }
    });
}

/// Head-range-scoped attention for the tensor-parallel shard path: one
/// shard's heads `h0..h1` of a query block, against that shard's own
/// KV-arena view.
///
/// * `q` — **compact** `(t, (h1-h0) * head_dim)` row-major: the shard's
///   local wq output (RoPE applied), holding only its own heads'
///   columns.
/// * `cache` — the shard's [`KvSource`], holding only kv heads
///   `kv0..` of the global model; `kv0` maps global kv-head indices to
///   this local view (`local = global_kv - kv0`).
/// * `ctx` — the **full-width** shared `(t, n_heads * head_dim)`
///   buffer; head `h` writes its global `h * head_dim` column span, so
///   N shards covering disjoint head ranges reassemble exactly the
///   buffer [`attention_block`] writes.  Callers guarantee disjoint
///   head ranges across concurrent shard lanes.
///
/// Runs serially — the shard lanes themselves are the parallelism.
/// Per head the math is [`attn_head`] with identical tiling and
/// accumulation order, so a head partition is bit-identical to the
/// unsharded kernel for any shard count (the same argument the
/// `parallel_chunks` head dispatch already relies on).
#[allow(clippy::too_many_arguments)]
pub fn attention_block_range<S: KvSource>(cfg: &ModelConfig, q: &[f32],
                                          cache: &S, pos0: usize,
                                          t: usize, h0: usize, h1: usize,
                                          kv0: usize,
                                          scratch: &mut AttnScratch,
                                          ctx: &SharedMut<f32>) {
    if t == 0 || h0 == h1 {
        return;
    }
    let hd = cfg.head_dim();
    let rep = cfg.n_heads / cfg.n_kv_heads;
    let d = cfg.n_heads * hd;
    let lw = (h1 - h0) * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert!(q.len() >= t * lw);
    debug_assert!(cache.len() >= pos0 + t, "block K/V not in cache yet");
    scratch.ensure(h1 - h0, t, hd);
    for (k, hs) in scratch.heads[..h1 - h0].iter_mut().enumerate() {
        let h = h0 + k;
        attn_head(q, lw, k * hd, cache, h / rep - kv0, hd, d, h * hd,
                  scale, pos0, t, hs, ctx);
    }
}

/// Single-token attention for a whole batch of decode slots in one
/// fork-join dispatch: the work range is the flattened
/// `slot x head` grid, so the coalesced decode tick is no longer
/// serialized per sequence (the last per-sequence stage after PR 1/2).
///
/// * `q` — `(n_slots, n_heads * head_dim)` row-major, RoPE applied;
///   slot `i`'s query sits at its cache's last position
///   (`caches[i].len() - 1`, K/V already appended).
/// * `caches` — each slot's own [`KvSource`] for this layer (the
///   coalesced decode tick passes one paged arena view per slot);
///   lengths may differ per slot (ragged contexts).
/// * `ctx` — `(n_slots, n_heads * head_dim)` output.
///
/// Per (slot, head) the math runs through the same [`attn_head`] as
/// the per-slot path, in the same order — cross-slot execution is
/// bit-identical to calling [`attention_block`] slot by slot, which
/// `tests/parallel_parity.rs` pins.  Slot-major flattening keeps one
/// slot's heads contiguous so a worker's chunk re-reads that slot's
/// KV pages from warm cache.
pub fn attention_cross_slots<S: KvSource>(cfg: &ModelConfig, q: &[f32],
                                          caches: &[S],
                                          scratch: &mut AttnScratch,
                                          pool: Option<&ThreadPool>,
                                          ctx: &mut [f32]) {
    let n_slots = caches.len();
    if n_slots == 0 {
        return;
    }
    let hd = cfg.head_dim();
    let n_heads = cfg.n_heads;
    let rep = n_heads / cfg.n_kv_heads;
    let d = n_heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert!(q.len() >= n_slots * d && ctx.len() >= n_slots * d);
    scratch.ensure(n_slots * n_heads, 1, hd);

    // total (query, key) x head_dim volume across the whole batch —
    // the same per-head formula attention_block gates on (slot i alone
    // contributes t*(pos0+t)*hd = len_i*hd), so per-slot and
    // cross-slot dispatch open at consistent shapes
    let total_positions: usize = caches.iter().map(|c| c.len()).sum();
    let work = hd * total_positions;
    let parallel = n_slots * n_heads > 1
        && work >= ATTN_PARALLEL_MIN_WORK_GATE.get()
        && pool.is_some_and(|p| p.size() > 1);
    let cptr = SharedCtx(ctx.as_mut_ptr());
    let hptr = SharedHeads(scratch.heads.as_mut_ptr());
    let run_range = |lo: usize, hi: usize| {
        for idx in lo..hi {
            let (slot, h) = (idx / n_heads, idx % n_heads);
            let cache = &caches[slot];
            debug_assert!(cache.len() >= 1, "slot K/V not appended yet");
            let pos0 = cache.len() - 1;
            // SAFETY: disjoint (slot, head) index ranges — this
            // worker is the only one touching heads[idx] and the
            // (slot, h) span of ctx (attn_head writes only its own
            // head_dim span of row `slot`).
            let hs = unsafe { &mut *hptr.0.add(idx) };
            let qrow = &q[slot * d..(slot + 1) * d];
            let crow = SharedCtx(unsafe { cptr.0.add(slot * d) });
            attn_head(qrow, d, h * hd, cache, h / rep, hd, d, h * hd,
                      scale, pos0, 1, hs, &crow);
        }
    };
    if !parallel {
        run_range(0, n_slots * n_heads);
        return;
    }
    pool.unwrap().parallel_chunks(n_slots * n_heads, run_range);
}

/// One head's tiled online-softmax pass over all t queries.
///
/// Generic over [`KvSource`]: each tile's K (then V) rows are fetched
/// as one contiguous `k_run`/`v_run` — tiles are anchored at absolute
/// multiples of `ATTN_TILE` and `KV_PAGE % ATTN_TILE == 0`, so a run
/// never straddles a page and the inner loops stream the exact same
/// contiguous memory over a paged arena view as over the slab oracle
/// (bit-identical results for f32 pages; pinned by
/// `tests/kv_arena.rs`).
///
/// Quantized runs dequantize **inside the dot product**: the run's
/// absmax step is uniform over the tile (one page, one head, one
/// side), so the K side accumulates `q . k_int` in f32 and applies
/// `k_step * softmax_scale` once per position, and the V side folds
/// `v_step` into the per-position softmax weight before the
/// `head_dim`-wide accumulate — no scratch dequant buffers, no extra
/// pass over the cache, and the streamed bytes shrink 4x (i8) / 8x
/// (i4).
/// Layout parameters (decoupled so the shard path can feed a compact
/// per-shard q while writing the full-width shared ctx):
/// * `qs`/`qcol` — q row stride and this head's column offset within a
///   q row (`d` / `h*hd` for the unsharded callers).
/// * `d`/`ccol` — ctx row stride and this head's ctx column offset
///   (always the global `h*hd` so shards reassemble the full buffer).
/// * `kvh` — the head's kv index *in the given cache* (callers subtract
///   the shard's kv base for local arena views).
#[allow(clippy::too_many_arguments)]
fn attn_head<S: KvSource>(q: &[f32], qs: usize, qcol: usize, cache: &S,
                          kvh: usize, hd: usize, d: usize, ccol: usize,
                          scale: f32, pos0: usize, t: usize,
                          hs: &mut HeadScratch, ctx: &SharedCtx) {
    let HeadScratch { m, l, acc, s } = hs;
    m[..t].fill(f32::NEG_INFINITY);
    l[..t].fill(0.0);
    acc[..t * hd].fill(0.0);

    let total = pos0 + t;
    let mut p0 = 0usize;
    while p0 < total {
        let p1 = (p0 + ATTN_TILE).min(total);
        // first query whose causal range reaches this tile
        let i0 = p0.saturating_sub(pos0);
        for i in i0..t {
            // query i sees positions 0..=pos0 + i (limit > p0 always:
            // for i >= i0, pos0 + i + 1 >= p0 + 1)
            let limit = (pos0 + i + 1).min(p1);
            let qh = &q[i * qs + qcol..i * qs + qcol + hd];
            // scores for the visible part of the tile
            let mut tmax = f32::NEG_INFINITY;
            match cache.k_run(kvh, p0, limit) {
                KvRun::F32(run) => {
                    for (j, kr) in run.chunks_exact(hd).enumerate() {
                        let mut dot = 0f32;
                        for (a, b) in qh.iter().zip(kr) {
                            dot += a * b;
                        }
                        let sc = dot * scale;
                        s[j] = sc;
                        tmax = tmax.max(sc);
                    }
                }
                KvRun::I8 { data, scale: kstep } => {
                    // page-uniform step folded into the softmax scale:
                    // one multiply per position, none per element.
                    // SIMD-dispatched fused-dequant dot (ISSUE 9):
                    // codes convert exactly to f32 and the wide kernel
                    // follows the fixed lane-blocked reduction order,
                    // so both dispatch arms land inside the existing
                    // quantized oracle bounds.
                    let ks = kstep * scale;
                    for (j, kr) in data.chunks_exact(hd).enumerate() {
                        let sc = simd::dot_f32_i8(qh, kr) * ks;
                        s[j] = sc;
                        tmax = tmax.max(sc);
                    }
                }
                KvRun::U4 { data, scale: kstep } => {
                    let ks = kstep * scale;
                    for (j, kr) in data.chunks_exact(hd / 2)
                        .enumerate() {
                        let sc = simd::dot_f32_u4(qh, kr) * ks;
                        s[j] = sc;
                        tmax = tmax.max(sc);
                    }
                }
            }
            // online-softmax rescale (coef = 0 on the first tile since
            // m starts at -inf, leaving the zeroed state untouched)
            let m_new = m[i].max(tmax);
            let coef = (m[i] - m_new).exp();
            let acc_i = &mut acc[i * hd..(i + 1) * hd];
            if coef != 1.0 {
                l[i] *= coef;
                simd::scale_in_place(acc_i, coef);
            }
            let mut li = l[i];
            match cache.v_run(kvh, p0, limit) {
                KvRun::F32(run) => {
                    for (j, vr) in run.chunks_exact(hd).enumerate() {
                        let w = (s[j] - m_new).exp();
                        li += w;
                        for (a, vv) in acc_i.iter_mut().zip(vr) {
                            *a += w * vv;
                        }
                    }
                }
                KvRun::I8 { data, scale: vstep } => {
                    for (j, vr) in data.chunks_exact(hd).enumerate() {
                        let w = (s[j] - m_new).exp();
                        li += w;
                        // the denominator uses the true weight; the
                        // dequant step rides the weight into the
                        // accumulate (one multiply per position)
                        let wv = w * vstep;
                        simd::axpy_f32_i8(acc_i, wv, vr);
                    }
                }
                KvRun::U4 { data, scale: vstep } => {
                    for (j, vr) in data.chunks_exact(hd / 2)
                        .enumerate() {
                        let w = (s[j] - m_new).exp();
                        li += w;
                        let wv = w * vstep;
                        simd::axpy_f32_u4(acc_i, wv, vr);
                    }
                }
            }
            l[i] = li;
            m[i] = m_new;
        }
        p0 = p1;
    }

    // normalise into this head's span of each ctx row
    for i in 0..t {
        let inv = 1.0 / l[i];
        let src = &acc[i * hd..(i + 1) * hd];
        // SAFETY: the (i, ccol) span is written by this head only; see
        // caller.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(ctx.0.add(i * d + ccol), hd)
        };
        for (o, a) in dst.iter_mut().zip(src) {
            *o = a * inv;
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar oracle
// ---------------------------------------------------------------------------

/// Dot of a query row with row `j` of a run, dequant step applied —
/// the scalar-oracle helper (the tiled kernel writes the match around
/// its tile loops instead).  For f32 runs the expression is the exact
/// sum the pre-quantization oracle computed.
#[inline]
fn run_dot(qh: &[f32], run: &KvRun<'_>, j: usize, hd: usize) -> f32 {
    match run {
        KvRun::F32(r) => {
            let row = &r[j * hd..(j + 1) * hd];
            qh.iter().zip(row).map(|(a, b)| a * b).sum()
        }
        KvRun::I8 { data, scale } => {
            let row = &data[j * hd..(j + 1) * hd];
            simd::dot_f32_i8(qh, row) * scale
        }
        KvRun::U4 { data, scale } => {
            let row = &data[j * (hd / 2)..(j + 1) * (hd / 2)];
            simd::dot_f32_u4(qh, row) * scale
        }
    }
}

/// `out += w * row_j(run)` with the dequant step folded into `w` —
/// the V-side scalar-oracle helper.
#[inline]
fn run_axpy(out: &mut [f32], w: f32, run: &KvRun<'_>, j: usize,
            hd: usize) {
    match run {
        KvRun::F32(r) => {
            let row = &r[j * hd..(j + 1) * hd];
            for (o, vv) in out.iter_mut().zip(row) {
                *o += w * vv;
            }
        }
        KvRun::I8 { data, scale } => {
            let row = &data[j * hd..(j + 1) * hd];
            simd::axpy_f32_i8(out, w * scale, row);
        }
        KvRun::U4 { data, scale } => {
            let row = &data[j * (hd / 2)..(j + 1) * (hd / 2)];
            simd::axpy_f32_u4(out, w * scale, row);
        }
    }
}

/// One-position causal attention over the cache (GQA-aware) — the
/// scalar oracle the tiled kernel is pinned against
/// (`tests/attention_parity.rs`).  Two-pass softmax, head-serial.
/// Generic over [`KvSource`] like the tiled kernel; single-position
/// runs never straddle a page, so any source (and any storage
/// precision) works.
pub fn attention_step<S: KvSource>(q: &[f32], cache: &S,
                                   cfg: &ModelConfig, pos: usize,
                                   scores: &mut [f32], ctx: &mut [f32]) {
    let hd = cfg.head_dim();
    let rep = cfg.n_heads / cfg.n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    ctx.fill(0.0);
    for h in 0..cfg.n_heads {
        let kvh = h / rep;
        let qh = &q[h * hd..(h + 1) * hd];
        // scores
        let mut maxs = f32::NEG_INFINITY;
        for p in 0..=pos {
            let kh = cache.k_run(kvh, p, p + 1);
            let dot = run_dot(qh, &kh, 0, hd);
            scores[p] = dot * scale;
            maxs = maxs.max(scores[p]);
        }
        // softmax
        let mut denom = 0f32;
        for sc in scores[..=pos].iter_mut() {
            *sc = (*sc - maxs).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        // weighted sum of V — branch-free: every position contributes
        // its exact softmax weight (the old `w < 1e-8` skip both
        // mispredicted in the innermost loop and made the output
        // subtly non-softmax)
        let out = &mut ctx[h * hd..(h + 1) * hd];
        for p in 0..=pos {
            let w = scores[p] * inv;
            let vh = cache.v_run(kvh, p, p + 1);
            run_axpy(out, w, &vh, 0, hd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
                max_seq: usize) -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab_size: 4,
            d_model: n_heads * hd,
            n_layers: 1,
            n_heads,
            n_kv_heads,
            d_ff: 4,
            max_seq_len: max_seq,
            rope_theta: 1e4,
            norm_eps: 1e-5,
            n_slices: 4,
            slice_bits: 2,
            group_size: 4,
            router_hidden: 4,
        }
    }

    #[test]
    fn rope_cache_matches_scalar_rope() {
        let (hd, theta) = (8usize, 1e4f32);
        let mut cache = RopeCache::new(hd, theta);
        cache.ensure(17);
        assert_eq!(cache.positions(), 17);
        let mut rng = crate::util::prng::Pcg::new(3);
        for pos in [0usize, 1, 7, 16] {
            let mut a = rng.normal_vec(2 * hd, 1.0); // two heads
            let mut b = a.clone();
            rope(&mut a, pos, hd, theta);
            cache.apply(&mut b, pos);
            assert_eq!(a, b, "pos {pos}: cached RoPE must be \
                              bit-identical to the scalar reference");
        }
    }

    #[test]
    fn rope_cache_grows_monotonically() {
        let mut c = RopeCache::new(4, 1e4);
        c.ensure(3);
        let r3 = c.row(2).0.to_vec();
        c.ensure(10);
        assert_eq!(c.row(2).0, &r3[..], "growth must not move old rows");
        c.ensure(5); // shrink request is a no-op
        assert_eq!(c.positions(), 10);
    }

    #[test]
    fn append_kv_block_matches_rope_then_push() {
        let (n_kv, hd, t) = (2usize, 4usize, 3usize);
        let w = n_kv * hd;
        let mut rng = crate::util::prng::Pcg::new(9);
        let k_block = rng.normal_vec(t * w, 1.0);
        let v_block = rng.normal_vec(t * w, 1.0);

        let mut want = KvCache::new(8, n_kv, hd);
        for i in 0..t {
            let mut k_row = k_block[i * w..(i + 1) * w].to_vec();
            rope(&mut k_row, i, hd, 1e4);
            want.push(&k_row, &v_block[i * w..(i + 1) * w]);
        }

        let mut rc = RopeCache::new(hd, 1e4);
        rc.ensure(t);
        let mut got = KvCache::new(8, n_kv, hd);
        assert_eq!(append_kv_block(&mut got, &rc, &k_block, &v_block, t),
                   0);
        assert_eq!(got.len, t);
        assert_eq!(got.k, want.k);
        assert_eq!(got.v, want.v);
    }

    #[test]
    fn attention_uniform_values() {
        // all K identical -> uniform weights -> ctx = mean of V
        let cfg = test_cfg(1, 1, 4, 8);
        let mut cache = KvCache::new(8, 1, 4);
        cache.push(&[1.0, 0.0, 0.0, 0.0], &[1.0, 0.0, 0.0, 0.0]);
        cache.push(&[1.0, 0.0, 0.0, 0.0], &[3.0, 0.0, 0.0, 0.0]);
        let q = vec![1.0, 0.0, 0.0, 0.0];
        let mut scores = vec![0f32; 8];
        let mut ctx = vec![0f32; 4];
        attention_step(&q, &cache, &cfg, 1, &mut scores, &mut ctx);
        assert!((ctx[0] - 2.0).abs() < 1e-5);
        // tiled kernel agrees
        let mut tiled = vec![0f32; 4];
        let mut sc = AttnScratch::new();
        attention_block(&cfg, &q, &cache, 1, 1, &mut sc, None,
                        &mut tiled);
        assert!((tiled[0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn tiled_matches_oracle_multi_tile_gqa() {
        // spans several ATTN_TILE boundaries with grouped kv heads
        let (n_heads, n_kv, hd) = (4usize, 2usize, 8usize);
        let max_seq = 3 * ATTN_TILE + 5;
        let cfg = test_cfg(n_heads, n_kv, hd, max_seq);
        let d = n_heads * hd;
        let w = n_kv * hd;
        let mut rng = crate::util::prng::Pcg::new(21);
        let mut cache = KvCache::new(max_seq, n_kv, hd);
        for _ in 0..max_seq {
            cache.push(&rng.normal_vec(w, 1.0), &rng.normal_vec(w, 1.0));
        }
        let t = 7;
        let pos0 = max_seq - t;
        let q = rng.normal_vec(t * d, 1.0);

        let mut want = vec![0f32; t * d];
        let mut scores = vec![0f32; max_seq];
        for i in 0..t {
            // the oracle's `pos` argument enforces causality; later
            // cache rows are simply never indexed
            attention_step(&q[i * d..(i + 1) * d], &cache, &cfg,
                           pos0 + i, &mut scores,
                           &mut want[i * d..(i + 1) * d]);
        }

        let mut got = vec![0f32; t * d];
        let mut sc = AttnScratch::new();
        attention_block(&cfg, &q, &cache, pos0, t, &mut sc, None,
                        &mut got);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4,
                    "ctx[{i}]: tiled {a} vs oracle {b}");
        }
    }
}
