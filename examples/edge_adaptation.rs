//! Edge-device adaptation scenario (paper §1 motivation): resources
//! oscillate; the controller continuously retunes the routing threshold
//! delta / target bits, and we measure the quality (per-token NLL) the
//! device actually delivers in each regime — without reloading or
//! repacking a single weight.
//!
//!     cargo run --release --example edge_adaptation

use anyhow::Result;
use mobiquant::coordinator::controller::{ControllerConfig,
                                         ElasticController};
use mobiquant::data::{corpus, ppl};
use mobiquant::mobiq::artifact::Bundle;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;

fn main() -> Result<()> {
    let dir = mobiquant::artifacts_dir();
    let bundle = Bundle::load(dir.join("tiny-s.mobiq"))?;
    let model = Model::load(&bundle, BackendKind::Mobiq)?;
    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)?;

    let mut ctl = ElasticController::new(ControllerConfig::default());
    println!("{:>6} {:>9} {:>11} {:>9} {:>9}",
             "phase", "pressure", "target_bits", "ppl", "avg_bits");
    // sweep a contention cycle: calm -> rising -> peak -> recovery
    for (phase, pressure) in [("calm", 0.0), ("rise", 0.35),
                              ("peak", 0.95), ("cool", 0.5),
                              ("calm2", 0.05)] {
        let precision = ctl.update(pressure, 0.0);
        let r = ppl::evaluate(&model, &toks, precision, 128, 6)?;
        println!("{:>6} {:>9.2} {:>11.2} {:>9.4} {:>9.2}",
                 phase, pressure, ctl.target_bits(), r.ppl, r.avg_bits);
    }
    println!("\ncontroller switched precision {} times; weights were \
              packed ONCE at build time", ctl.switches());

    // manual delta override (Eq. 10): the raw elasticity knob
    println!("\nmanual delta sweep at target 4 bits:");
    for delta in [-0.8f32, -0.4, 0.0, 0.4, 0.8] {
        let r = ppl::evaluate(&model, &toks,
                              Precision::Elastic { target_bits: 4.0, delta },
                              128, 4)?;
        println!("  delta {delta:>5.1} -> avg bits {:.2}, ppl {:.4}",
                 r.avg_bits, r.ppl);
    }
    Ok(())
}
