//! Native LLaMA-style transformer decode — the L3 request path.
//!
//! Mirrors python/compile/model.py exactly (RMSNorm, interleaved-pair
//! RoPE, optional GQA, SwiGLU); golden vectors exported in the bundle pin
//! the two implementations together (rust/tests/integration.rs).

pub mod attention;
pub mod kvcache;
pub mod shard;
pub mod speculative;
pub mod transformer;
pub mod weights;

pub use kvcache::{KvArena, KvHandle, KvPrecision, KvRun, KvShards,
                  KvSource, PageLocation, SeqCheckpoint, SwapSummary,
                  KV_PAGE};
pub use shard::{shard_range, ShardPlan, ShardRuntime};
pub use speculative::{SpecCapture, SpecConfig, SpecRound, SpecState};
pub use transformer::{DecodeStats, Model};
pub use weights::{LinearBackend, ModelConfig};
