"""SmoothQuant baseline (ref. [13]).

Migrates activation outliers into weights with a fixed-alpha per-channel
smoothing factor:

    s_j = max|x_j|^alpha / max|W_j,:|^(1-alpha),     alpha = 0.5

then quantizes the smoothed weight W'[j,:] = s_j * W[j,:]; activations are
divided by s at inference (same ``act_scale`` mechanism as AWQ).
"""

from __future__ import annotations

import numpy as np

from .gptq import StaticQuantLinear, rtn_record


def smooth_quantize(w: np.ndarray, x: np.ndarray, bits: int,
                    group_size: int, alpha: float = 0.5
                    ) -> StaticQuantLinear:
    w = np.asarray(w, np.float64)
    x = np.asarray(x, np.float64)
    a_max = np.max(np.abs(x), axis=0) + 1e-8          # (d_in,)
    w_max = np.max(np.abs(w), axis=1) + 1e-8          # (d_in,)
    s = (a_max ** alpha) / (w_max ** (1.0 - alpha))
    s = np.maximum(s / (np.median(s) + 1e-12), 1e-4)  # normalise median to 1
    rec = rtn_record((w * s[:, None]).astype(np.float32), bits, group_size)
    return rec._replace(act_scale=s.astype(np.float32),
                        transform="chan_scale")
