//! §Perf §KV-Arena §KV-Quant — paged KV arena study (EXPERIMENTS.md).
//!
//! Questions, all on the synthetic model (no `make artifacts`):
//!
//! 1. **Decode throughput over the arena** at f32 / i8 / u4 page
//!    storage and ctx ∈ {256, 1024, 4096} — quantized pages stream
//!    4x/8x fewer cache bytes through the attention tiles (dequant is
//!    fused into the dot product, scale hoisted per tile), so
//!    long-context decode should never be slower and gets faster as
//!    the KV stream stops fitting in cache.
//! 2. **Resident KV memory** at 1 / 8 / 32 slots x each precision:
//!    measured arena residency vs the eager f32 slab deployment
//!    (`KvFootprint::eager_bytes`) and vs the f32 arena — the ISSUE's
//!    >= 4x (i8) / 8x (u4) residency reduction at equal slot count.
//! 3. **Admission under a fixed budget**: the scheduler, given the
//!    same `kv_page_budget`, must admit >= 4x the slots when requests
//!    store KV at i8 (byte-accurate worst-case reservation).
//! 4. **Shared-prefix prefill**: a 512-token shared prompt attached
//!    from the prefix pages + a 32-token unique tail, vs cold-filling
//!    all 544 tokens — the "million users, one system prompt" path.
//!
//! Writes `target/bench_reports/BENCH_kv.json`.

use std::sync::mpsc;
use std::time::Instant;

use mobiquant::bench_support::{kv_footprint, synth_model_shaped};
use mobiquant::coordinator::batcher::Batcher;
use mobiquant::coordinator::controller::{ControllerConfig,
                                         ElasticController};
use mobiquant::coordinator::request::Request;
use mobiquant::coordinator::scheduler::Scheduler;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::transformer::{DecodeSlot, DecodeStats};
use mobiquant::model::{KvPrecision, KV_PAGE};
use mobiquant::util::bench::{black_box, Suite};

const KV_PRECS: [KvPrecision; 3] =
    [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4];

fn main() {
    let mut suite = Suite::new("BENCH_kv");
    suite.header();
    let prec = Precision::Fixed(2);

    // one model shape for the residency/prefix studies: 4h/2kv,
    // head_dim 16, 2 layers, ctx budget 1024 (so the shared 512-token
    // prompt fits with a tail and generation headroom)
    let model = synth_model_shaped(201, 4, 2, 1024);
    let cfg = &model.cfg;
    let fp = kv_footprint(cfg);

    // ---------------- residency vs slots x precision ------------------
    let prompt_len = 48usize; // short sequences: under one page
    for &kvp in &KV_PRECS {
        for &n_slots in &[1usize, 8, 32] {
            let mut arena = model.new_arena(n_slots);
            let mut scratch = model.new_scratch();
            let seqs: Vec<_> = (0..n_slots)
                .map(|_| arena.alloc_seq_at(kvp))
                .collect();
            let mut dstats = DecodeStats::new(cfg.n_layers);
            for (s, &seq) in seqs.iter().enumerate() {
                let p: Vec<u32> = (0..prompt_len)
                    .map(|i| ((i * 5 + 7 * s + 2) % 256) as u32)
                    .collect();
                model.prefill(&p, &mut arena, seq, prec, &mut scratch,
                              &mut dstats).unwrap();
            }
            // measured arena residency vs the eager f32 slab
            // deployment AND vs the f32 arena at the same slot count
            // (the ISSUE >= 4x/8x claims)
            let resident = arena.resident_bytes();
            let eager = fp.eager_bytes(n_slots);
            let lens = vec![prompt_len; n_slots];
            let f32_arena = fp.paged_bytes(&lens);
            // acceptance bars, asserted so regenerated rows can never
            // silently regress: >= 4x vs eager slabs, and exactly the
            // storage ratio vs an f32 arena (4x i8 / 8x u4)
            assert!(eager >= 4 * resident,
                    "{} {n_slots} slots: eager {eager} < 4x resident \
                     {resident}", kvp.label());
            assert_eq!(resident * fp.page_bytes()
                           / fp.page_bytes_at(kvp),
                       f32_arena,
                       "{} {n_slots} slots: measured residency is not \
                        the exact storage ratio", kvp.label());
            suite.row(&format!("kv memory {} {n_slots} slots @len \
                                {prompt_len}", kvp.label()),
                      &[
                ("arena_resident_bytes", resident as f64),
                ("eager_slab_bytes", eager as f64),
                ("eager_over_arena",
                 eager as f64 / resident.max(1) as f64),
                ("f32_arena_over_arena",
                 f32_arena as f64 / resident.max(1) as f64),
            ]);
        }
    }

    // ---------------- decode tok/s vs ctx x precision -----------------
    // taller ctx budget so the 4096 point exists; decode advances one
    // token per tick from the prefilled context
    let tall = synth_model_shaped(202, 4, 2, 4352);
    let tcfg = &tall.cfg;
    for &kvp in &KV_PRECS {
        for &ctx in &[256usize, 1024, 4096] {
            let mut arena = tall.new_arena(1);
            let mut scratch = tall.new_scratch();
            let seq = arena.alloc_seq_at(kvp);
            let mut dstats = DecodeStats::new(tcfg.n_layers);
            let prompt: Vec<u32> = (0..ctx)
                .map(|i| ((i * 5 + 2) % 256) as u32)
                .collect();
            tall.prefill(&prompt, &mut arena, seq, prec, &mut scratch,
                         &mut dstats).unwrap();
            let mut stats = DecodeStats::new(tcfg.n_layers);
            let ns = suite.bench(
                &format!("decode {} ctx {ctx}", kvp.label()), || {
                    if arena.seq_len(seq) + 1 >= tcfg.max_seq_len {
                        arena.reset_seq(seq);
                        tall.prefill(&prompt, &mut arena, seq, prec,
                                     &mut scratch, &mut dstats)
                            .unwrap();
                    }
                    let mut slots = [DecodeSlot {
                        token: 65,
                        seq,
                        stats: &mut stats,
                    }];
                    tall.decode_batch(&mut slots, &mut arena, prec,
                                      &mut scratch).unwrap();
                    black_box(scratch.block.logits[0]);
                });
            suite.row(&format!("decode {} ctx {ctx} summary",
                               kvp.label()),
                      &[
                ("ns_per_tok", ns),
                ("tok_s", 1.0 / (ns * 1e-9)),
                ("resident_bytes", arena.resident_bytes() as f64),
            ]);
        }
    }

    // ---------------- scheduler admission under a fixed budget --------
    // worst case per request: prompt 48 + max_new 16 = 1 page/layer =
    // 2 pages at f32; a 4-page budget admits 2 f32 slots, 8 i8 slots,
    // 16 u4 slots — byte-accurate reservation converts storage savings
    // straight into concurrency
    let mut admitted_by_prec = Vec::new();
    for &kvp in &KV_PRECS {
        let batcher = Batcher::new(64, 64).with_kv_budget(4);
        let controller = ElasticController::new(ControllerConfig {
            min_bits: 4.0,
            max_bits: 4.0,
            ..ControllerConfig::default()
        });
        let mut sched = Scheduler::new(&model, batcher, controller);
        let mut rxs = Vec::new();
        for id in 0..32u64 {
            let (tx, rx) = mpsc::channel();
            sched.submit(Request {
                id,
                prompt: (0..prompt_len)
                    .map(|i| ((i * 3 + id as usize) % 256) as u32)
                    .collect(),
                max_new_tokens: 16,
                kv_precision: kvp,
                submitted: Instant::now(),
                reply: tx,
            });
            rxs.push(rx);
        }
        sched.tick(0.0).unwrap();
        admitted_by_prec.push(sched.n_active());
        suite.row(&format!("admission {} under 4-page budget",
                           kvp.label()),
                  &[
            ("slots_admitted", sched.n_active() as f64),
            ("queued", sched.batcher.queued() as f64),
        ]);
    }
    // asserted acceptance bar: byte-accurate reservation converts the
    // 4x/8x storage savings into >= 4x/8x admitted slots
    assert!(admitted_by_prec[1] >= 4 * admitted_by_prec[0],
            "i8 admitted {} < 4x f32's {}", admitted_by_prec[1],
            admitted_by_prec[0]);
    assert!(admitted_by_prec[2] >= 8 * admitted_by_prec[0],
            "u4 admitted {} < 8x f32's {}", admitted_by_prec[2],
            admitted_by_prec[0]);

    // ---------------- shared-prefix vs cold prefill -------------------
    let shared_len = 8 * KV_PAGE; // 512 tokens, page-aligned
    let tail_len = 32usize;
    let total = shared_len + tail_len;
    let prompt: Vec<u32> = (0..total)
        .map(|i| ((i * 7 + 3) % 256) as u32)
        .collect();
    let mut arena = model.new_arena(4);
    let mut scratch = model.new_scratch();
    let mut pstats = DecodeStats::new(cfg.n_layers);
    // the donor sequence holds the shared prompt's pages (what the
    // scheduler's prefix cache parks)
    let donor = arena.alloc_seq();
    model.prefill(&prompt[..shared_len], &mut arena, donor, prec,
                  &mut scratch, &mut pstats).unwrap();

    let ns_cold = suite.bench(
        &format!("cold prefill {total} tokens"), || {
            let h = arena.alloc_seq();
            model.prefill(&prompt, &mut arena, h, prec, &mut scratch,
                          &mut pstats).unwrap();
            black_box(scratch.logits[0]);
            arena.free_seq(h);
        });
    let ns_warm = suite.bench(
        &format!("shared prefill {tail_len}-token tail"), || {
            let h = arena.fork_prefix(donor, shared_len);
            model.prefill(&prompt[shared_len..], &mut arena, h, prec,
                          &mut scratch, &mut pstats).unwrap();
            black_box(scratch.logits[0]);
            arena.free_seq(h);
        });
    suite.row("shared-prefix summary", &[
        ("prefill_skip_fraction", shared_len as f64 / total as f64),
        ("cold_over_shared", ns_cold / ns_warm),
        ("ns_cold", ns_cold),
        ("ns_shared_tail", ns_warm),
        ("shared_pages_per_layer",
         (shared_len / KV_PAGE) as f64),
    ]);

    suite.note(&format!(
        "targets: eager_over_arena >= 4x at 32 short f32 slots (exact \
         ratio = max_seq/pages: {}/{} pages) and 4x/8x more for i8/u4 \
         (f32_arena_over_arena is exactly 4/8 — scales are side \
         metadata); admission: i8 admits >= 4x the f32 slots under \
         the same 4-page budget; decode tok/s must not regress vs f32 \
         at any ctx; prefill_skip_fraction {:.3} >= 0.9 by \
         construction",
        cfg.max_seq_len / KV_PAGE,
        (prompt_len + KV_PAGE - 1) / KV_PAGE,
        shared_len as f64 / total as f64));
    suite.finish();
}
