//! §Perf §Attention — tiled head-parallel online-softmax attention
//! study (EXPERIMENTS.md §Perf §Attention).
//!
//! Compares three kernels over the head-major KV cache at serving-like
//! shapes, sweeping context length x head/GQA configs:
//!   * `attention_step` — the scalar oracle: head-serial, two-pass
//!     softmax, one query position per call (the pre-refactor hot
//!     path, called T times per block),
//!   * `attention_block` serial — whole query block in one pass,
//!     position tiles streamed once and reused by every query,
//!     online softmax (no full score buffer),
//!   * `attention_block` + `ThreadPool` — the same kernel with heads
//!     split over contiguous worker chunks.
//!
//! Two shapes per (config, ctx): a prefill block (T = min(64, ctx)
//! queries ending at ctx) and a single-query decode step at position
//! ctx - 1.  Writes `target/bench_reports/BENCH_attn.json`.

use std::sync::Arc;

use mobiquant::model::attention::{attention_block, attention_step,
                                  AttnScratch};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::weights::ModelConfig;
use mobiquant::util::bench::{black_box, Suite};
use mobiquant::util::prng::Pcg;
use mobiquant::util::threadpool::{default_threads, ThreadPool};

fn attn_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
            ctx: usize) -> ModelConfig {
    ModelConfig {
        name: "bench".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads,
        d_ff: 16,
        max_seq_len: ctx,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

fn main() {
    let mut suite = Suite::new("BENCH_attn");
    suite.header();
    let mut rng = Pcg::new(17);
    let pool = Arc::new(ThreadPool::new(default_threads()));
    suite.note(&format!("parallel rows use {} worker threads",
                        pool.size()));
    let hd = 64usize;

    for &(tag, n_heads, n_kv) in &[("mha-8h", 8usize, 8usize),
                                   ("gqa-8h-2kv", 8, 2),
                                   ("gqa-32h-8kv", 32, 8)] {
        let d = n_heads * hd;
        let w = n_kv * hd;
        for &ctx in &[64usize, 256, 1024] {
            let cfg = attn_cfg(n_heads, n_kv, hd, ctx);
            let mut cache = KvCache::new(ctx, n_kv, hd);
            for _ in 0..ctx {
                let k = rng.normal_vec(w, 1.0);
                let v = rng.normal_vec(w, 1.0);
                cache.push(&k, &v);
            }
            let mut scores = vec![0f32; ctx];
            let mut sc = AttnScratch::new();

            // -- prefill block: T queries ending at ctx --
            let t = 64usize.min(ctx);
            let pos0 = ctx - t;
            let q = rng.normal_vec(t * d, 1.0);
            let mut out = vec![0f32; t * d];
            let label = format!("{tag} ctx={ctx} T={t}");
            let ns_scalar = suite.bench(&format!("{label} scalar"), || {
                for i in 0..t {
                    attention_step(&q[i * d..(i + 1) * d], &cache, &cfg,
                                   pos0 + i, &mut scores,
                                   &mut out[i * d..(i + 1) * d]);
                }
                black_box(out[0]);
            });
            let ns_tiled = suite.bench(&format!("{label} tiled"), || {
                attention_block(&cfg, &q, &cache, pos0, t, &mut sc,
                                None, &mut out);
                black_box(out[0]);
            });
            let ns_par = suite.bench(
                &format!("{label} tiled+parallel"), || {
                    attention_block(&cfg, &q, &cache, pos0, t, &mut sc,
                                    Some(&pool), &mut out);
                    black_box(out[0]);
                });
            let toks = t as f64;
            suite.row(&format!("{label} summary"), &[
                ("tok_s_scalar", toks / (ns_scalar * 1e-9)),
                ("tok_s_tiled", toks / (ns_tiled * 1e-9)),
                ("tok_s_parallel", toks / (ns_par * 1e-9)),
                ("tiled_speedup", ns_scalar / ns_tiled),
                ("parallel_speedup", ns_scalar / ns_par),
            ]);

            // -- decode step: one query at position ctx - 1 --
            let pos = ctx - 1;
            let q1 = rng.normal_vec(d, 1.0);
            let mut out1 = vec![0f32; d];
            let dlabel = format!("{tag} ctx={ctx} decode");
            let ns_dscalar =
                suite.bench(&format!("{dlabel} scalar"), || {
                    attention_step(&q1, &cache, &cfg, pos, &mut scores,
                                   &mut out1);
                    black_box(out1[0]);
                });
            let ns_dtiled = suite.bench(&format!("{dlabel} tiled"), || {
                attention_block(&cfg, &q1, &cache, pos, 1, &mut sc,
                                None, &mut out1);
                black_box(out1[0]);
            });
            // parallel row only differs from tiled once the work gate
            // (ATTN_PARALLEL_MIN_WORK) opens — it doubles as a gate
            // tuning probe
            let ns_dpar = suite.bench(
                &format!("{dlabel} tiled+parallel"), || {
                    attention_block(&cfg, &q1, &cache, pos, 1, &mut sc,
                                    Some(&pool), &mut out1);
                    black_box(out1[0]);
                });
            suite.row(&format!("{dlabel} summary"), &[
                ("ns_scalar", ns_dscalar),
                ("ns_tiled", ns_dtiled),
                ("ns_parallel", ns_dpar),
                ("decode_tiled_speedup", ns_dscalar / ns_dtiled),
                ("decode_parallel_speedup", ns_dscalar / ns_dpar),
            ]);
        }
    }
    suite.note("targets: tiled+parallel >= 2x scalar tokens/s at \
                ctx=1024 on every head config; tiled (serial) alone \
                should already win from K/V tile reuse across the \
                query block (EXPERIMENTS.md §Perf §Attention)");
    suite.finish();
}
