//! API-compatible PJRT stub for builds without the vendored `xla`
//! crate (the default).  Everything type-checks — the integration
//! tests and the CLI `pjrt` subcommand compile unchanged — but any
//! attempt to construct a client or run a module reports the missing
//! feature.  Callers already skip gracefully when the HLO artifacts
//! are absent, which is the only situation where these paths would be
//! reachable on a stub build.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

const NO_PJRT: &str = "built without the `pjrt` feature: vendor the \
                       `xla` crate and rebuild with `--features pjrt` \
                       (see rust/Cargo.toml)";

/// Placeholder for `xla::Literal`.
pub struct Literal;

pub struct PjrtRuntime;

pub struct HloModule {
    pub path: PathBuf,
}

impl PjrtRuntime {
    /// Always false on the stub: tests skip instead of unwrapping a
    /// client that cannot exist.
    pub fn available() -> bool {
        false
    }

    pub fn cpu() -> Result<PjrtRuntime> {
        bail!(NO_PJRT)
    }

    pub fn platform(&self) -> String {
        "stub (no pjrt feature)".to_string()
    }

    pub fn load(&self, path: impl AsRef<Path>) -> Result<HloModule> {
        let _ = path;
        bail!(NO_PJRT)
    }
}

impl HloModule {
    pub fn run_f32(&self, inputs: &[Literal]) -> Result<Vec<f32>> {
        let _ = inputs;
        bail!(NO_PJRT)
    }

    pub fn run_tokens(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let _ = tokens;
        bail!(NO_PJRT)
    }
}

pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let _ = (data, dims);
    bail!(NO_PJRT)
}

pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let _ = (data, dims);
    bail!(NO_PJRT)
}
