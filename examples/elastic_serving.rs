//! End-to-end serving driver — the headline example (EXPERIMENTS.md §E2E).
//!
//! Loads the pretrained+calibrated tiny-m model from the artifact bundle
//! and serves a Poisson request trace through the elastic coordinator
//! under a three-phase resource-pressure signal (calm -> contended ->
//! recovering), reporting per-request latency, throughput, and the
//! precision trace the controller actually delivered.
//!
//!     cargo run --release --example elastic_serving [-- --model tiny-m]

use anyhow::Result;
use mobiquant::coordinator::{Server, ServerConfig};
use mobiquant::data::{corpus, workload};
use mobiquant::mobiq::artifact::Bundle;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;
use mobiquant::util::cli::Args;
use mobiquant::util::stats;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let name = args.get_or("model", "tiny-m");
    let dir = mobiquant::artifacts_dir();
    let path = dir.join(format!("{name}.mobiq"));
    let path = if path.exists() { path } else { dir.join("tiny-s.mobiq") };
    let bundle = Bundle::load(&path)?;
    let model = Model::load(&bundle, BackendKind::Mobiq)?;
    println!("serving on {} ({} params-ish linears, elastic 2-8 bit)",
             model.cfg.name, model.cfg.n_layers * 7);

    let toks = corpus::load_tokens(&dir, "wiki", corpus::Split::Valid)?;
    let trace_cfg = workload::TraceConfig {
        n_requests: args.get_usize("requests", 16),
        rate_per_s: args.get_f64("rate", 4.0),
        prompt_len: (16, 48),
        gen_len: (12, 32),
        seed: 7,
    };
    let trace = workload::generate_trace(&toks, &trace_cfg);
    let total_ms = *trace.last().map(|r| &r.arrival_ms).unwrap_or(&1000.0)
        + 2000.0;
    let pressure = workload::PressureSignal::phased(total_ms);

    let server = Server::start(model, ServerConfig::default());
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for spec in &trace {
        let now_ms = t0.elapsed().as_secs_f64() * 1000.0;
        if spec.arrival_ms > now_ms {
            std::thread::sleep(std::time::Duration::from_millis(
                (spec.arrival_ms - now_ms) as u64));
        }
        let p = pressure.at(t0.elapsed().as_secs_f64() * 1000.0);
        server.set_pressure(p);
        let (id, rx) = server.submit(spec.prompt.clone(),
                                     spec.max_new_tokens);
        pending.push((id, p, rx));
    }

    println!("\n{:>4} {:>9} {:>9} {:>9} {:>8} {:>9}",
             "req", "press", "queue_ms", "total_ms", "tok/s", "avg_bits");
    let mut lat = Vec::new();
    let mut bits = Vec::new();
    for (id, p, rx) in pending {
        let r = rx.recv()?;
        println!("{:>4} {:>9.2} {:>9.0} {:>9.0} {:>8.1} {:>9.2}",
                 id, p, r.metrics.queue_ms, r.metrics.total_ms,
                 r.decode_tokens_per_s(), r.metrics.avg_bits);
        lat.push(r.metrics.total_ms);
        bits.push((p, r.metrics.avg_bits));
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown()?;
    println!("\n{}", metrics.summary(wall));
    println!("p50 request latency: {:.0} ms,  p95: {:.0} ms",
             stats::percentile(&lat, 50.0), stats::percentile(&lat, 95.0));

    // elasticity check: contended-phase requests should use fewer bits
    let calm: Vec<f64> = bits.iter().filter(|(p, _)| *p < 0.3)
        .map(|(_, b)| *b).collect();
    let hot: Vec<f64> = bits.iter().filter(|(p, _)| *p > 0.7)
        .map(|(_, b)| *b).collect();
    if !calm.is_empty() && !hot.is_empty() {
        println!("avg bits under calm pressure:      {:.2}",
                 stats::mean(&calm));
        println!("avg bits under contended pressure: {:.2}",
                 stats::mean(&hot));
        println!("-> precision adapted at runtime with zero repacking");
    }
    Ok(())
}
