//! Paged KV arena parity + lifecycle, against the slab oracle and the
//! serving stack.  All on synthetic models/caches, so no
//! `make artifacts` is needed.
//!
//! The parity bar (ISSUE 4): forwards over the arena must be
//! bit-identical to the slab oracle under the same kernel, including
//! sequences spanning page boundaries (T = 63/64/65/129) and COW forks
//! mid-page; the scheduler must queue (not panic) when the arena runs
//! out of pages, and retire must make those pages reusable.

use std::sync::mpsc;
use std::time::Instant;

use mobiquant::bench_support::synth_model_shaped;
use mobiquant::coordinator::batcher::Batcher;
use mobiquant::coordinator::controller::{ControllerConfig,
                                         ElasticController};
use mobiquant::coordinator::request::{Request, Response};
use mobiquant::coordinator::scheduler::Scheduler;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::attention::{append_kv_block, attention_block,
                                  AttnScratch, RopeCache};
use mobiquant::model::kvcache::KvCache;
use mobiquant::model::transformer::DecodeStats;
use mobiquant::model::weights::ModelConfig;
use mobiquant::model::{KvArena, KV_PAGE};
use mobiquant::util::prng::Pcg;

const TOL: f32 = 1e-4;

fn attn_cfg(n_heads: usize, n_kv_heads: usize, hd: usize,
            max_seq: usize) -> ModelConfig {
    ModelConfig {
        name: "arena".into(),
        vocab_size: 16,
        d_model: n_heads * hd,
        n_layers: 1,
        n_heads,
        n_kv_heads,
        d_ff: 16,
        max_seq_len: max_seq,
        rope_theta: 1e4,
        norm_eps: 1e-5,
        n_slices: 4,
        slice_bits: 2,
        group_size: 32,
        router_hidden: 8,
    }
}

/// The core storage-parity pin: identical K/V blocks appended to the
/// contiguous slab and to the paged arena (in uneven chunks that cross
/// page boundaries), then the *same* tiled kernel over both — outputs
/// must be exactly equal, at lengths straddling 1 and 2 page seams.
#[test]
fn arena_attention_bit_identical_to_slab_oracle() {
    let (n_heads, n_kv, hd) = (4usize, 2usize, 16usize);
    let max_seq = 3 * KV_PAGE;
    let cfg = attn_cfg(n_heads, n_kv, hd, max_seq);
    let d = cfg.d_model;
    let w = n_kv * hd;
    for &t in &[63usize, 64, 65, 129] {
        let mut rng = Pcg::new(300 + t as u64);
        let k_block = rng.normal_vec(t * w, 1.0);
        let v_block = rng.normal_vec(t * w, 1.0);
        let mut rope = RopeCache::new(hd, cfg.rope_theta);
        rope.ensure(t);

        let mut slab = KvCache::new(max_seq, n_kv, hd);
        let mut arena = KvArena::new(1, max_seq, n_kv, hd, 4);
        let seq = arena.alloc_seq();
        // uneven appends so arena page claims land mid-block
        let mut fed = 0usize;
        for chunk in [50usize, 31, 64, 64] {
            let n = chunk.min(t - fed);
            if n == 0 {
                break;
            }
            let lo = fed * w;
            append_kv_block(&mut slab, &rope,
                            &k_block[lo..(fed + n) * w],
                            &v_block[lo..(fed + n) * w], n);
            arena.append_kv_block(seq, 0, &rope,
                                  &k_block[lo..(fed + n) * w],
                                  &v_block[lo..(fed + n) * w], n)
                .unwrap();
            fed += n;
        }
        assert_eq!(fed, t);
        assert_eq!(arena.seq_len(seq), t);

        let mut sc = AttnScratch::new();
        // whole-block prefill shape
        let q = rng.normal_vec(t * d, 1.0);
        let mut out_slab = vec![0f32; t * d];
        attention_block(&cfg, &q, &slab, 0, t, &mut sc, None,
                        &mut out_slab);
        let mut out_arena = vec![0f32; t * d];
        let view = arena.layer(seq, 0);
        attention_block(&cfg, &q, &view, 0, t, &mut sc, None,
                        &mut out_arena);
        assert_eq!(out_slab, out_arena,
                   "T={t}: paged attention diverged from the slab");

        // single-query decode shape at the last position
        let q1 = rng.normal_vec(d, 1.0);
        let mut d_slab = vec![0f32; d];
        attention_block(&cfg, &q1, &slab, t - 1, 1, &mut sc, None,
                        &mut d_slab);
        let mut d_arena = vec![0f32; d];
        let view = arena.layer(seq, 0);
        attention_block(&cfg, &q1, &view, t - 1, 1, &mut sc, None,
                        &mut d_arena);
        assert_eq!(d_slab, d_arena, "T={t}: decode shape diverged");
    }
}

/// Arena-backed `forward_logits` (block prefill) vs per-token
/// `decode_step` right below / at / past page seams.
#[test]
fn arena_forward_parity_at_page_boundaries() {
    let model = synth_model_shaped(7, 4, 2, 160);
    let prec = Precision::Fixed(2);
    for &t in &[KV_PAGE - 1, KV_PAGE, KV_PAGE + 1, 2 * KV_PAGE + 1] {
        let tokens: Vec<u32> = (0..t)
            .map(|i| ((i * 7 + 3) % model.cfg.vocab_size) as u32)
            .collect();
        let block = model.forward_logits(&tokens, prec).unwrap();

        let (mut arena, seq) = model.new_kv();
        let mut scratch = model.new_scratch();
        let mut stats = DecodeStats::new(model.cfg.n_layers);
        let mut per_tok = Vec::new();
        for &tok in &tokens {
            model.decode_step(tok, &mut arena, seq, prec, &mut scratch,
                              &mut stats).unwrap();
            per_tok.extend_from_slice(&scratch.logits);
        }
        assert_eq!(block.len(), per_tok.len());
        for (i, (a, b)) in block.iter().zip(&per_tok).enumerate() {
            assert!((a - b).abs() < TOL,
                    "T={t} logits[{i}]: block {a} vs per-token {b}");
        }
    }
}

/// COW fork mid-page: a fork sharing 100 positions (1.5 pages) and its
/// source, fed the same continuation, must produce bit-identical
/// logits — and both must equal a cold sequence fed the full stream
/// (same kernels, same positions, so exactly equal, not just close).
#[test]
fn cow_fork_mid_page_parity() {
    let model = synth_model_shaped(95, 4, 2, 256);
    let prec = Precision::Fixed(2);
    let mut arena = model.new_arena(4);
    let mut scratch = model.new_scratch();
    let tok = |i: usize| ((i * 5 + 11) % model.cfg.vocab_size) as u32;
    let shared = 100usize; // mid-page: 1 full page + 36 rows
    let cont: Vec<u32> = (0..20).map(|i| tok(1000 + i)).collect();

    let a = arena.alloc_seq();
    let mut sa = DecodeStats::new(model.cfg.n_layers);
    for i in 0..shared {
        model.decode_step(tok(i), &mut arena, a, prec, &mut scratch,
                          &mut sa).unwrap();
    }
    let resident_before = arena.resident_pages();
    let b = arena.fork_prefix(a, shared);
    assert_eq!(arena.resident_pages(), resident_before,
               "fork must not copy pages");
    assert_eq!(arena.seq_len(b), shared);

    // source first (COWs the shared partial page), then the fork
    let mut la = Vec::new();
    for &tk in &cont {
        model.decode_step(tk, &mut arena, a, prec, &mut scratch,
                          &mut sa).unwrap();
        la.extend_from_slice(&scratch.logits);
    }
    let mut sb = DecodeStats::new(model.cfg.n_layers);
    let mut lb = Vec::new();
    for &tk in &cont {
        model.decode_step(tk, &mut arena, b, prec, &mut scratch,
                          &mut sb).unwrap();
        lb.extend_from_slice(&scratch.logits);
    }
    assert_eq!(la, lb, "fork diverged from source after COW");

    // cold recompute of the full stream
    let c = arena.alloc_seq();
    let mut sc = DecodeStats::new(model.cfg.n_layers);
    let mut lc = Vec::new();
    for i in 0..shared {
        model.decode_step(tok(i), &mut arena, c, prec, &mut scratch,
                          &mut sc).unwrap();
    }
    for &tk in &cont {
        model.decode_step(tk, &mut arena, c, prec, &mut scratch,
                          &mut sc).unwrap();
        lc.extend_from_slice(&scratch.logits);
    }
    assert_eq!(la, lc, "shared-page path diverged from cold recompute");

    // lifecycle: freeing all three returns every page
    arena.free_seq(a);
    arena.free_seq(b);
    arena.free_seq(c);
    assert_eq!(arena.resident_pages(), 0);
}

fn mk_req(id: u64, prompt: Vec<u32>, max_new: usize)
          -> (Request, mpsc::Receiver<Response>) {
    let (tx, rx) = mpsc::channel();
    (Request {
        id,
        prompt,
        max_new_tokens: max_new,
        submitted: Instant::now(),
        reply: tx,
    }, rx)
}

fn fixed_controller() -> ElasticController {
    ElasticController::new(ControllerConfig {
        min_bits: 4.0,
        max_bits: 4.0,
        ..ControllerConfig::default()
    })
}

/// Out-of-pages admission backpressure: with a 3-page budget and
/// 2-page requests, only one sequence runs at a time; the others queue
/// (no panic), retire frees their pages, and everyone completes.
#[test]
fn out_of_pages_queues_and_retire_readmits() {
    let model = synth_model_shaped(93, 4, 2, 128);
    assert_eq!(model.cfg.n_layers, 2);
    let batcher = Batcher::new(4, 16).with_kv_budget(3);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    let mut rxs = Vec::new();
    for id in 0..3u64 {
        // distinct 40-token prompts, 4 new tokens: worst case is
        // 2 layers x 1 page = 2 pages per request
        let prompt: Vec<u32> = (0..40)
            .map(|i| ((i * 3 + 7 * id as usize) % 256) as u32)
            .collect();
        let (req, rx) = mk_req(id, prompt, 4);
        sched.submit(req);
        rxs.push(rx);
    }
    sched.tick(0.0).unwrap();
    assert_eq!(sched.n_active(), 1,
               "page budget must gate admission to one sequence");
    assert_eq!(sched.batcher.queued(), 2);
    assert!(sched.batcher.deferred() > 0,
            "blocked admissions must be counted, not panicked");

    sched.run_to_completion(|_| 0.0).unwrap();
    for rx in rxs {
        let resp = rx.try_recv().expect("every queued request finishes");
        assert_eq!(resp.metrics.generated_tokens, 4);
    }
    assert_eq!(sched.metrics.requests_completed, 3);
    assert!(sched.metrics.admissions_deferred > 0);
    assert!(sched.arena.peak_resident_pages() <= 3,
            "budget must bound peak residency");
    assert_eq!(sched.arena.resident_pages(), 0,
               "retire must return all pages (no prefix cache here: \
                prompts are shorter than one page)");
}

/// Shared-prefix serving: a second identical prompt forks the cached
/// prefix pages instead of recomputing them — same output tokens, one
/// cache hit, one page-aligned prefix worth of prefill skipped.
#[test]
fn prefix_sharing_matches_cold_run() {
    let model = synth_model_shaped(91, 4, 2, 256);
    let batcher = Batcher::new(2, 16);
    let mut sched = Scheduler::new(&model, batcher, fixed_controller());
    let prompt: Vec<u32> = (0..80)
        .map(|i| ((i * 7 + 3) % 256) as u32)
        .collect();

    let (r1, rx1) = mk_req(0, prompt.clone(), 6);
    sched.submit(r1);
    sched.run_to_completion(|_| 0.0).unwrap();
    let cold = rx1.try_recv().expect("cold response");
    assert_eq!(sched.metrics.prefix_hits, 0);
    assert_eq!(sched.metrics.prefix_misses, 1);

    let (r2, rx2) = mk_req(1, prompt.clone(), 6);
    sched.submit(r2);
    sched.run_to_completion(|_| 0.0).unwrap();
    let warm = rx2.try_recv().expect("warm response");

    assert_eq!(warm.tokens, cold.tokens,
               "shared-prefix decode must match the cold run exactly");
    assert_eq!(sched.metrics.prefix_hits, 1);
    // 80-token prompt -> one full page (64) is shareable
    assert_eq!(sched.metrics.prefix_tokens_reused, KV_PAGE as u64);
    assert!(sched.metrics.prefix_hit_rate() > 0.49);
}
