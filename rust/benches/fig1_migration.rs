//! Fig. 1 + §3 motivation + App. E.1 (Tab. 4) / E.2 (Tab. 5) —
//! outlier migration and the calibration/inference-mismatch gap.
//!
//! Left panel: OmniQuant-lite calibrated at 3-bit evaluated at 4-bit vs
//! calibrated at 4-bit; plus the counterintuitive "keep top-10% outlier
//! tokens at 3-bit" variant; plus MoBiQuant.
//! Right panel: per-token error distributions at 3 vs 4 bit and the
//! top-outlier overlap fraction (41% LLaMA / 16% Mistral analogues).

use mobiquant::analysis;
use mobiquant::bench_support as bs;
use mobiquant::data::ppl;
use mobiquant::mobiq::engine::Precision;
use mobiquant::model::weights::BackendKind;
use mobiquant::model::Model;
use mobiquant::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("fig1_migration");
    suite.header();
    let windows = bs::eval_windows(6);
    let toks = match bs::valid_tokens("wiki") {
        Ok(t) => t,
        Err(_) => {
            suite.note("no corpus; run `make artifacts`");
            suite.finish();
            return;
        }
    };

    for mname in bs::models_available() {
        let Some(bundle) = bs::try_bundle(&mname) else { continue };
        suite.note(&format!("--- model {mname} ---"));

        // ---- Fig. 1 left: mismatch PPL bars --------------------------
        if bundle.static_methods().contains(&"omniquant3".to_string())
            && bundle.static_methods().contains(&"omniquant4".to_string())
        {
            let m_match = Model::load(
                &bundle, BackendKind::Static("omniquant4".into())).unwrap();
            let ppl_match = ppl::evaluate(&m_match, &toks,
                                          Precision::Fixed(4), 128,
                                          windows).unwrap().ppl;
            let m_mis = bs::mismatch_model(&bundle, "omniquant3", 4)
                .unwrap();
            let ppl_mis = ppl::evaluate(&m_mis, &toks, Precision::Fixed(4),
                                        128, windows).unwrap().ppl;

            // token-adaptive variant: top-10% 3-bit-calib outlier tokens
            // stay on the 3-bit weights (per-step model switch).
            let m_3bit = Model::load(
                &bundle, BackendKind::Static("omniquant3".into())).unwrap();
            let probe = 0usize.min(m_3bit.cfg.n_layers - 1);
            let fpm = Model::load(&bundle, BackendKind::Fp32).unwrap();
            let n_probe = (windows * 128).min(toks.len() - 1);
            let xs = fpm.attn_inputs(&toks[..n_probe], probe,
                                     Precision::Fixed(4)).unwrap();
            let (w_fp, d_in, d_out) = bs::fp_weight(&bundle, probe, "wq")
                .unwrap();
            let w3 = match m_3bit.layers[probe].linear("wq") {
                Ok(mobiquant::model::LinearBackend::Static(s)) =>
                    s.weights.clone(),
                _ => unreachable!(),
            };
            let err3 = analysis::token_errors(&w_fp, &w3, &xs, d_in,
                                              d_out);
            let outliers: std::collections::HashSet<usize> =
                analysis::top_outliers(&err3, 0.10).into_iter().collect();
            // dual-model eval: outlier positions use the 3-bit weights
            let ppl_adaptive = dual_model_ppl(&m_mis, &m_3bit, &outliers,
                                              &toks, 128, windows);

            let mobiq = Model::load(&bundle, BackendKind::Mobiq).unwrap();
            let ppl_mobiq = ppl::evaluate(&mobiq, &toks,
                                          Precision::elastic(4.0), 128,
                                          windows).unwrap().ppl;
            suite.row(&format!("{mname} Fig1L infer@4bit"), &[
                ("calib4", ppl_match),
                ("calib3", ppl_mis),
                ("calib3+top10pct@3b", ppl_adaptive),
                ("MoBiQ", ppl_mobiq),
            ]);
        }

        // ---- Fig. 1 right + Tab. 4/5: migration statistics -----------
        for method in ["omniquant", "awq"] {
            let (Some(k3), Some(k4)) = (
                bundle.static_methods().iter()
                    .find(|k| *k == &format!("{method}3")).cloned(),
                bundle.static_methods().iter()
                    .find(|k| *k == &format!("{method}4")).cloned(),
            ) else { continue };
            let fpm = Model::load(&bundle, BackendKind::Fp32).unwrap();
            let probe = fpm.cfg.n_layers / 2;
            let n_probe = (windows * 128).min(toks.len() - 1).min(768);
            let xs = fpm.attn_inputs(&toks[..n_probe], probe,
                                     Precision::Fixed(4)).unwrap();
            let (w_fp, d_in, d_out) = bs::fp_weight(&bundle, probe, "wq")
                .unwrap();
            let m3 = Model::load(&bundle, BackendKind::Static(k3))
                .unwrap();
            let m4 = Model::load(&bundle, BackendKind::Static(k4))
                .unwrap();
            let get_w = |m: &Model| match m.layers[probe].linear("wq") {
                Ok(mobiquant::model::LinearBackend::Static(s)) =>
                    s.weights.clone(),
                _ => unreachable!(),
            };
            let e3 = analysis::token_errors(&w_fp, &get_w(&m3), &xs, d_in,
                                            d_out);
            let e4 = analysis::token_errors(&w_fp, &get_w(&m4), &xs, d_in,
                                            d_out);
            let overlap = analysis::outlier_overlap(&e3, &e4, 0.10);
            let s3 = analysis::summarize(&e3);
            let s4 = analysis::summarize(&e4);
            suite.row(&format!("{mname} {method} migration"), &[
                ("top10_overlap", overlap),
                ("tail_mass_3b", s3.top10_mass),
                ("tail_mass_4b", s4.top10_mass),
                ("p99_3b", s3.p99),
                ("p99_4b", s4.p99),
            ]);
        }

        // ---- Tab. 4 analogue: AWQ mismatch grid ----------------------
        if bundle.static_methods().contains(&"awq3".to_string()) {
            let mut cells = Vec::new();
            for (calib, infer) in [(3u32, 3u32), (3, 4), (4, 3), (4, 4)] {
                let key = format!("awq{calib}");
                let model = if calib == infer {
                    Model::load(&bundle, BackendKind::Static(key)).unwrap()
                } else {
                    bs::mismatch_model(&bundle, &key, infer).unwrap()
                };
                let r = ppl::evaluate(&model, &toks, Precision::Fixed(4),
                                      128, windows).unwrap();
                cells.push((format!("c{calib}i{infer}"), r.ppl));
            }
            let named: Vec<(&str, f64)> = cells.iter()
                .map(|(k, v)| (k.as_str(), *v)).collect();
            suite.row(&format!("{mname} Tab4 AWQ gap"), &named);
        }

        // ---- Tab. 6 analogue: QuaRot mismatch gap --------------------
        for method in ["quarot", "omniquant"] {
            let key = format!("{method}4");
            if !bundle.static_methods().contains(&key) {
                continue;
            }
            let m_match = Model::load(
                &bundle, BackendKind::Static(key.clone())).unwrap();
            let p_match = ppl::evaluate(&m_match, &toks,
                                        Precision::Fixed(4), 128,
                                        windows).unwrap().ppl;
            let m_mis = bs::mismatch_model(&bundle, &key, 3).unwrap();
            let p_mis = ppl::evaluate(&m_mis, &toks, Precision::Fixed(4),
                                      128, windows).unwrap().ppl;
            suite.row(&format!("{mname} Tab6 {method} c4->i3"), &[
                ("infer4", p_match), ("infer3", p_mis),
                ("gap", p_mis - p_match),
            ]);
        }
    }
    suite.note("paper shape: calib/infer mismatch degrades static PTQ; \
                token-adaptive low-bit fallback recovers part; MoBiQ \
                closes the gap; top-outlier overlap well below 100%");
    suite.finish();
}

/// PPL with per-position model switching (outlier positions -> model B).
fn dual_model_ppl(a: &Model, b: &Model,
                  b_positions: &std::collections::HashSet<usize>,
                  tokens: &[u32], window: usize, max_windows: usize)
                  -> f64 {
    let mut total = 0f64;
    let mut count = 0usize;
    // a and b share a config, so one arena serves whichever model the
    // position routing picks
    let (mut arena, seq) = a.new_kv();
    let mut scratch = a.new_scratch();
    let mut stats = mobiquant::model::DecodeStats::new(a.cfg.n_layers);
    let n = ((tokens.len() - 1) / window).min(max_windows);
    for i in 0..n {
        let chunk = &tokens[i * window..i * window + window + 1];
        arena.reset_seq(seq);
        for (j, &t) in chunk[..window].iter().enumerate() {
            let global = i * window + j;
            let m = if b_positions.contains(&global) { b } else { a };
            m.decode_step(t, &mut arena, seq, Precision::Fixed(4),
                          &mut scratch, &mut stats).unwrap();
            total += ppl::nll_of(&scratch.logits, chunk[j + 1]);
            count += 1;
        }
    }
    (total / count as f64).exp()
}
