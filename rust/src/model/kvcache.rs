//! Per-sequence KV cache with slab allocation.
//!
//! The coordinator serves many concurrent sequences; each gets a cache
//! slot sized to max_seq_len.  The manager tracks allocation so the
//! scheduler can apply backpressure when memory runs out (Fig. 7-style
//! memory accounting feeds from here too).

/// KV tensors of one sequence: (max_seq, n_kv_heads * head_dim) each.
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub width: usize,
    pub max_seq: usize,
}

impl KvCache {
    pub fn new(max_seq: usize, width: usize) -> KvCache {
        KvCache {
            k: vec![0f32; max_seq * width],
            v: vec![0f32; max_seq * width],
            len: 0,
            width,
            max_seq,
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Append one position's K/V rows; returns the position index.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> usize {
        assert!(self.len < self.max_seq, "kv cache overflow");
        let pos = self.len;
        self.k[pos * self.width..(pos + 1) * self.width]
            .copy_from_slice(k_row);
        self.v[pos * self.width..(pos + 1) * self.width]
            .copy_from_slice(v_row);
        self.len += 1;
        pos
    }

    #[inline]
    pub fn k_at(&self, pos: usize) -> &[f32] {
        &self.k[pos * self.width..(pos + 1) * self.width]
    }

    #[inline]
    pub fn v_at(&self, pos: usize) -> &[f32] {
        &self.v[pos * self.width..(pos + 1) * self.width]
    }

    pub fn nbytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// All layers' caches for one sequence.
pub struct SequenceKv {
    pub layers: Vec<KvCache>,
}

impl SequenceKv {
    pub fn new(n_layers: usize, max_seq: usize, width: usize) -> SequenceKv {
        SequenceKv {
            layers: (0..n_layers).map(|_| KvCache::new(max_seq, width))
                .collect(),
        }
    }
    pub fn len(&self) -> usize {
        self.layers.first().map(|c| c.len).unwrap_or(0)
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn reset(&mut self) {
        for c in &mut self.layers {
            c.reset();
        }
    }
    pub fn nbytes(&self) -> usize {
        self.layers.iter().map(|c| c.nbytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut c = KvCache::new(4, 2);
        assert_eq!(c.push(&[1.0, 2.0], &[3.0, 4.0]), 0);
        assert_eq!(c.push(&[5.0, 6.0], &[7.0, 8.0]), 1);
        assert_eq!(c.k_at(0), &[1.0, 2.0]);
        assert_eq!(c.v_at(1), &[7.0, 8.0]);
        assert_eq!(c.len, 2);
        c.reset();
        assert_eq!(c.len, 0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1);
        c.push(&[0.0], &[0.0]);
        c.push(&[0.0], &[0.0]);
    }

    #[test]
    fn sequence_kv_sizes() {
        let s = SequenceKv::new(3, 8, 4);
        assert_eq!(s.len(), 0);
        assert_eq!(s.nbytes(), 3 * 2 * 8 * 4 * 4);
    }
}
