//! MoBiQuant linear engine: bit-plane slices + router + thresholds glued
//! into the object the transformer dispatches to on the request path.

use std::sync::Arc;

use anyhow::Result;

use super::artifact::Bundle;
use super::bitplane::PackedSlice;
use super::gemv::{gemm_lut_batch, gemm_lut_batch_parallel,
                  gemm_lut_batch_range, gemv_lut, gemv_lut_parallel,
                  gemv_lut_range, BatchLut, SharedOut, TokenLut};
use super::quantizer::GroupParams;
use super::router::{hard_mask, mask_bits, ratio_for_target_bits,
                    RouterMlp, ThresholdTable};
use crate::util::threadpool::ThreadPool;

/// Runtime precision policy for a forward pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precision {
    /// Use exactly the first k slices for every token (static reconstr.).
    Fixed(usize),
    /// Token-adaptive routing around a target average bit-width, with a
    /// global delta shift (Eq. 10) for runtime elasticity.
    Elastic { target_bits: f64, delta: f32 },
}

impl Precision {
    pub fn elastic(target_bits: f64) -> Precision {
        Precision::Elastic { target_bits, delta: 0.0 }
    }

    /// Replace the Eq. 10 global threshold shift of an elastic policy
    /// (no-op on `Fixed` — a static slice count has no threshold to
    /// shift).  The speculative draft path uses this to couple the
    /// router to the accept-rate feedback loop: a struggling draft
    /// lowers delta so sensitive tokens pick up extra residual slices
    /// (`mobiq::router::draft_delta`).
    pub fn with_delta(self, delta: f32) -> Precision {
        match self {
            Precision::Elastic { target_bits, .. } => {
                Precision::Elastic { target_bits, delta }
            }
            p => p,
        }
    }
}

/// One quantized linear layer (weights only live as bit-planes).
pub struct MobiqLinear {
    pub slices: Vec<PackedSlice>,
    pub base: GroupParams,
    pub router: RouterMlp,
    pub thresholds: ThresholdTable,
    pub d_in: usize,
    pub d_out: usize,
    pub slice_bits: usize,
    pub act_bits: Option<u32>, // optional activation quantization (Fig. 10)
}

/// Reusable per-thread scratch for the decode loop (allocation-free).
pub struct Scratch {
    pub lut: TokenLut,
    /// Per-token table blocks for the batched weight-stationary kernel
    /// (grows lazily to the largest batch seen).
    pub batch: BatchLut,
    pub router_hidden: Vec<f32>,
    pub scores: Vec<f32>,
    pub mask: Vec<bool>,
    pub xq: Vec<f32>,
    /// Shared kernel worker pool, plumbed down from the model/runtime.
    /// None or a size-1 pool selects the serial kernels.
    pub pool: Option<Arc<ThreadPool>>,
}

impl Scratch {
    pub fn new(max_d_in: usize, group_size: usize, hidden: usize,
               n_slices: usize) -> Scratch {
        Scratch {
            lut: TokenLut::new(max_d_in, group_size),
            batch: BatchLut::new(max_d_in, group_size),
            router_hidden: vec![0f32; hidden],
            scores: vec![0f32; n_slices - 1],
            mask: vec![false; n_slices],
            xq: vec![0f32; max_d_in],
            pool: None,
        }
    }

    /// Attach the shared worker pool the kernel paths should use.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Scratch {
        self.pool = Some(pool);
        self
    }
}

impl MobiqLinear {
    pub fn from_bundle(bundle: &Bundle, layer: usize, name: &str,
                       n_slices: usize, slice_bits: usize,
                       group_size: usize) -> Result<MobiqLinear> {
        let pre = format!("mobiq.layers.{layer}.{name}");
        let (sshape, scale) = bundle.f32(&format!("{pre}.scale"))?;
        let (_, zero) = bundle.f32(&format!("{pre}.zero"))?;
        let n_groups = sshape[0];
        let d_out = sshape[1];
        let d_in = n_groups * group_size;
        let mut slices = Vec::with_capacity(n_slices);
        for e in 0..n_slices {
            let t = bundle.tensor(&format!("{pre}.slice{e}.planes"))?;
            slices.push(PackedSlice::from_tensor(t.u64()?, &t.shape, d_in));
        }
        let (w1s, w1) = bundle.f32(&format!("{pre}.router.w1"))?;
        let hidden = w1s[1];
        let (_, b1) = bundle.f32(&format!("{pre}.router.b1"))?;
        let (w2s, w2) = bundle.f32(&format!("{pre}.router.w2"))?;
        let n_residual = w2s[1];
        let (_, b2) = bundle.f32(&format!("{pre}.router.b2"))?;
        let (_, quant) = bundle.f32(&format!("{pre}.quantiles"))?;
        Ok(MobiqLinear {
            slices,
            base: GroupParams {
                scale: scale.to_vec(),
                zero: zero.to_vec(),
                n_groups,
                d_out,
                bits: slice_bits as u32,
                group_size,
            },
            router: RouterMlp {
                w1: w1.to_vec(), b1: b1.to_vec(),
                w2: w2.to_vec(), b2: b2.to_vec(),
                d_in, hidden, n_residual,
            },
            thresholds: ThresholdTable { quantiles: quant.to_vec() },
            d_in, d_out,
            slice_bits,
            act_bits: None,
        })
    }

    /// Decide the slice mask for one token under a precision policy.
    /// Returns effective bits.  scratch.scores/mask are filled.
    pub fn route(&self, x: &[f32], precision: Precision,
                 scratch: &mut Scratch) -> usize {
        match precision {
            Precision::Fixed(k) => {
                for (e, m) in scratch.mask.iter_mut().enumerate() {
                    *m = e < k.max(1);
                }
                k.max(1) * self.slice_bits
            }
            Precision::Elastic { target_bits, delta } => {
                let rho = ratio_for_target_bits(
                    target_bits, self.slice_bits, self.slice_bits,
                    self.router.n_residual);
                let thr = self.thresholds.threshold_for_ratio(rho);
                self.router.scores_into(
                    x,
                    &mut scratch.router_hidden,
                    &mut scratch.scores,
                );
                hard_mask(&scratch.scores, thr, delta, &mut scratch.mask);
                mask_bits(&scratch.mask, self.slice_bits)
            }
        }
    }

    /// Full token forward: route + LUT GEMV.  The caller has already
    /// built scratch.lut for this x (shared across the layer's linears
    /// when inputs coincide is NOT safe here since inputs differ; build
    /// per linear input).  Returns effective bits used.
    pub fn forward_token(&self, x: &[f32], precision: Precision,
                         scratch: &mut Scratch, out: &mut [f32]) -> usize {
        let bits = self.route(x, precision, scratch);
        let x_eff: &[f32] = if let Some(ab) = self.act_bits {
            quantize_activation(x, ab, &mut scratch.xq[..x.len()]);
            // Rebuild the LUT on the quantized activation.
            &scratch.xq[..x.len()]
        } else {
            x
        };
        scratch.lut.build(x_eff, self.base.group_size);
        match scratch.pool.clone() {
            Some(pool) if pool.size() > 1 => {
                gemv_lut_parallel(&self.slices, &self.base, &scratch.lut,
                                  &scratch.mask, &pool, out)
            }
            _ => gemv_lut(&self.slices, &self.base, &scratch.lut,
                          &scratch.mask, out),
        }
        bits
    }

    /// Batched forward through the weight-stationary kernel: route every
    /// token, build all T LUT table blocks up front, then stream each
    /// plane word once per same-mask token group (§4.3 token
    /// permutation) — and in parallel over d_out chunks when a pool is
    /// attached.  xs: (T * d_in) row-major; out: (T * d_out).  Per-token
    /// effective bits land in `scratch.batch.bits`; returns their sum.
    pub fn forward_batch(&self, xs: &[f32], precision: Precision,
                         scratch: &mut Scratch, out: &mut [f32]) -> usize {
        let t = xs.len() / self.d_in;
        debug_assert_eq!(out.len(), t * self.d_out);
        scratch.batch.ensure_tokens(t);
        scratch.batch.bits.clear();
        let mut total_bits = 0usize;
        for i in 0..t {
            let x = &xs[i * self.d_in..(i + 1) * self.d_in];
            let bits = self.route(x, precision, scratch);
            total_bits += bits;
            scratch.batch.bits.push(bits);
            scratch.batch.set_mask(i, &scratch.mask);
            let x_eff: &[f32] = if let Some(ab) = self.act_bits {
                quantize_activation(x, ab, &mut scratch.xq[..x.len()]);
                &scratch.xq[..x.len()]
            } else {
                x
            };
            scratch.batch.build_token(i, x_eff, self.base.group_size);
        }
        match scratch.pool.clone() {
            Some(pool) if pool.size() > 1 => {
                gemm_lut_batch_parallel(&self.slices, &self.base,
                                        &scratch.batch, t, &pool, out)
            }
            _ => gemm_lut_batch(&self.slices, &self.base, &scratch.batch,
                                t, out),
        }
        total_bits
    }

    /// Column-sharded token forward for the tensor-parallel path:
    /// route on the **full** input (routing is replicated — every shard
    /// runs the same router on the same x and derives the same mask, so
    /// no cross-shard precision coordination is needed), then compute
    /// only output channels `o0..o1` into the compact `out`
    /// (len o1-o0).  Serial kernel — the shard lanes are the
    /// parallelism.  Per channel the accumulation order matches
    /// [`MobiqLinear::forward_token`] exactly (bit-identical
    /// reassembly).  Returns effective bits.
    pub fn forward_token_range(&self, x: &[f32], precision: Precision,
                               scratch: &mut Scratch, o0: usize,
                               o1: usize, out: &mut [f32]) -> usize {
        let bits = self.route(x, precision, scratch);
        let x_eff: &[f32] = if let Some(ab) = self.act_bits {
            quantize_activation(x, ab, &mut scratch.xq[..x.len()]);
            &scratch.xq[..x.len()]
        } else {
            x
        };
        scratch.lut.build(x_eff, self.base.group_size);
        gemv_lut_range(&self.slices, &self.base, &scratch.lut,
                       &scratch.mask, o0, o1, out);
        bits
    }

    /// Column-sharded batched forward: per-token routing and LUT builds
    /// exactly as [`MobiqLinear::forward_batch`] (replicated per shard;
    /// `scratch.batch.bits` is filled identically on every shard), then
    /// the weight-stationary kernel over channels `o0..o1` only,
    /// written at full `d_out` stride into the shared buffer.  Callers
    /// guarantee disjoint column ranges across concurrent lanes.
    /// Returns summed effective bits.
    pub fn forward_batch_range(&self, xs: &[f32], precision: Precision,
                               scratch: &mut Scratch, o0: usize,
                               o1: usize, out: &SharedOut) -> usize {
        let t = xs.len() / self.d_in;
        scratch.batch.ensure_tokens(t);
        scratch.batch.bits.clear();
        let mut total_bits = 0usize;
        for i in 0..t {
            let x = &xs[i * self.d_in..(i + 1) * self.d_in];
            let bits = self.route(x, precision, scratch);
            total_bits += bits;
            scratch.batch.bits.push(bits);
            scratch.batch.set_mask(i, &scratch.mask);
            let x_eff: &[f32] = if let Some(ab) = self.act_bits {
                quantize_activation(x, ab, &mut scratch.xq[..x.len()]);
                &scratch.xq[..x.len()]
            } else {
                x
            };
            scratch.batch.build_token(i, x_eff, self.base.group_size);
        }
        gemm_lut_batch_range(&self.slices, &self.base, &scratch.batch, t,
                             o0, o1, out);
        total_bits
    }

    /// Packed weight bytes actually loaded for a mask (traffic model).
    pub fn bytes_for_mask(&self, mask: &[bool]) -> usize {
        mask.iter().zip(&self.slices)
            .filter(|(&m, _)| m)
            .map(|(_, s)| s.nbytes())
            .sum()
    }

    pub fn nbytes_total(&self) -> usize {
        self.slices.iter().map(|s| s.nbytes()).sum::<usize>()
            + self.base.scale.len() * 8
            + self.router.w1.len() * 4
            + self.router.w2.len() * 4
    }
}

/// Per-token dynamic activation quantization (App. E.4 / Fig. 10):
/// symmetric min/max to `bits`, floor-aligned like the weights.
pub fn quantize_activation(x: &[f32], bits: u32, out: &mut [f32]) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let lo = lo.min(-1e-8);
    let hi = hi.max(1e-8);
    let levels = (1u64 << bits) as f32;
    let s = ((hi - lo) / levels).max(1e-12);
    let z = -lo / s;
    let maxq = levels - 1.0;
    for (o, &v) in out.iter_mut().zip(x) {
        let q = (v / s + z).floor().clamp(0.0, maxq);
        *o = s * (q - z + 0.5);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobiq::quantizer::decompose;
    use crate::util::prng::Pcg;

    pub(crate) fn synth_linear(rng: &mut Pcg, d_in: usize, d_out: usize)
                               -> MobiqLinear {
        let gs = 32;
        let w = rng.normal_vec(d_in * d_out, 0.2);
        let base = GroupParams::from_minmax(&w, d_in, d_out, 2, gs);
        let codes = decompose(&w, &base, 4);
        let slices = codes.iter()
            .map(|c| PackedSlice::from_codes(c, d_in, d_out, 2))
            .collect();
        MobiqLinear {
            slices,
            base,
            router: RouterMlp {
                w1: rng.normal_vec(d_in * 8, 0.2),
                b1: vec![0.0; 8],
                w2: rng.normal_vec(8 * 3, 0.2),
                b2: vec![0.0; 3],
                d_in, hidden: 8, n_residual: 3,
            },
            thresholds: ThresholdTable {
                quantiles: (0..129).map(|i| (i as f32 - 64.0) / 64.0)
                    .collect(),
            },
            d_in, d_out, slice_bits: 2, act_bits: None,
        }
    }

    #[test]
    fn fixed_precision_uses_k_slices() {
        let mut rng = Pcg::new(1);
        let lin = synth_linear(&mut rng, 64, 16);
        let x = rng.normal_vec(64, 1.0);
        let mut sc = Scratch::new(64, 32, 8, 4);
        let mut out = vec![0f32; 16];
        for k in 1..=4 {
            let bits = lin.forward_token(&x, Precision::Fixed(k), &mut sc,
                                         &mut out);
            assert_eq!(bits, 2 * k);
            assert_eq!(sc.mask.iter().filter(|&&m| m).count(), k);
        }
    }

    #[test]
    fn elastic_bits_monotone_in_target() {
        let mut rng = Pcg::new(2);
        let lin = synth_linear(&mut rng, 64, 16);
        let mut sc = Scratch::new(64, 32, 8, 4);
        let mut out = vec![0f32; 16];
        let xs: Vec<Vec<f32>> = (0..64).map(|_| rng.normal_vec(64, 1.0))
            .collect();
        let mut prev = 0.0;
        for target in [2.0, 4.0, 6.0, 8.0] {
            let total: usize = xs.iter().map(|x| {
                lin.forward_token(x, Precision::elastic(target), &mut sc,
                                  &mut out)
            }).sum();
            let avg = total as f64 / xs.len() as f64;
            assert!(avg + 1e-9 >= prev,
                    "avg bits must rise with target: {avg} < {prev}");
            prev = avg;
        }
    }

    #[test]
    fn delta_shift_prunes_slices() {
        let mut rng = Pcg::new(3);
        let lin = synth_linear(&mut rng, 64, 16);
        let mut sc = Scratch::new(64, 32, 8, 4);
        let x = rng.normal_vec(64, 1.0);
        let p_lo = Precision::Elastic { target_bits: 6.0, delta: -10.0 };
        let p_hi = Precision::Elastic { target_bits: 6.0, delta: 10.0 };
        let b_all = lin.route(&x, p_lo, &mut sc);
        assert_eq!(b_all, 8); // -inf threshold -> everything active
        let b_none = lin.route(&x, p_hi, &mut sc);
        assert_eq!(b_none, 2); // +inf threshold -> base slice only
    }

    #[test]
    fn batched_forward_matches_per_token() {
        let mut rng = Pcg::new(7);
        let lin = synth_linear(&mut rng, 64, 16);
        let mut sc = Scratch::new(64, 32, 8, 4);
        let t = 9;
        let xs: Vec<f32> = rng.normal_vec(64 * t, 1.0);
        let prec = Precision::elastic(4.0);
        let mut batched = vec![0f32; 16 * t];
        let bits_b = lin.forward_batch(&xs, prec, &mut sc, &mut batched);
        let mut single = vec![0f32; 16];
        let mut bits_s = 0usize;
        for i in 0..t {
            bits_s += lin.forward_token(&xs[i * 64..(i + 1) * 64], prec,
                                        &mut sc, &mut single);
            for (a, b) in single.iter().zip(&batched[i * 16..(i + 1) * 16])
            {
                assert!((a - b).abs() < 1e-5,
                        "token {i}: {a} vs {b}");
            }
        }
        assert_eq!(bits_b, bits_s);
    }

    #[test]
    fn range_forward_matches_full_bitwise() {
        // shard entry points: stitched column ranges must be bit-equal
        // to the full serial forwards, with identical routing records
        let mut rng = Pcg::new(9);
        let lin = synth_linear(&mut rng, 64, 24);
        let mut sc = Scratch::new(64, 32, 8, 4);
        let prec = Precision::elastic(4.0);
        let x = rng.normal_vec(64, 1.0);
        let mut full = vec![0f32; 24];
        let bits_full = lin.forward_token(&x, prec, &mut sc, &mut full);
        let mut stitched = vec![0f32; 24];
        let mut bits_r = Vec::new();
        for w in [0usize, 9, 24].windows(2) {
            bits_r.push(lin.forward_token_range(
                &x, prec, &mut sc, w[0], w[1],
                &mut stitched[w[0]..w[1]]));
        }
        assert_eq!(full, stitched);
        assert!(bits_r.iter().all(|&b| b == bits_full),
                "routing must be identical on every shard");

        let t = 5;
        let xs = rng.normal_vec(64 * t, 1.0);
        let mut bfull = vec![0f32; 24 * t];
        let bits_b = lin.forward_batch(&xs, prec, &mut sc, &mut bfull);
        let rec_full = sc.batch.bits.clone();
        let mut bst = vec![0f32; 24 * t];
        let optr = SharedOut(bst.as_mut_ptr());
        for w in [0usize, 7, 24].windows(2) {
            let bits = lin.forward_batch_range(&xs, prec, &mut sc, w[0],
                                               w[1], &optr);
            assert_eq!(bits, bits_b);
            assert_eq!(sc.batch.bits, rec_full,
                       "per-token bits record must replicate");
        }
        assert_eq!(bfull, bst);
    }

    #[test]
    fn act_quant_error_shrinks_with_bits() {
        let mut rng = Pcg::new(4);
        let x = rng.normal_vec(256, 1.0);
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 8] {
            let mut q = vec![0f32; 256];
            quantize_activation(&x, bits, &mut q);
            let err: f64 = x.iter().zip(&q)
                .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            assert!(err < prev);
            prev = err;
        }
    }

    #[test]
    fn traffic_proportional_to_mask() {
        let mut rng = Pcg::new(5);
        let lin = synth_linear(&mut rng, 64, 16);
        let b1 = lin.bytes_for_mask(&[true, false, false, false]);
        let b4 = lin.bytes_for_mask(&[true, true, true, true]);
        assert_eq!(b4, 4 * b1);
    }
}
