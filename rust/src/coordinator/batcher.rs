//! Admission queue + continuous batching.
//!
//! Requests park in a FIFO until the scheduler has a free sequence slot
//! (bounded by `max_active`) AND enough free KV budget for the
//! request's worst-case context (byte-accurate backpressure over the
//! paged arena — a request whose KV stores at i8 needs a quarter of an
//! f32 request's bytes; see [`Batcher::admit_with`]).  The invariants
//! checked
//! by the property tests: no request is lost or duplicated, admission
//! order is FIFO, and the active count never exceeds the cap.
//!
//! The batcher also owns the tick batching policy the scheduler
//! executes: how many prompt tokens a sequence prefills per tick, how
//! many sequences a coalesced decode step may fuse into one batched
//! kernel call, and the arena's page budget.

use std::collections::VecDeque;

use super::request::{PreemptedSeq, Request, RequestId};
use crate::model::SpecConfig;

pub struct Batcher {
    queue: VecDeque<Request>,
    /// Sequences evicted by the pressure ladder, waiting to re-prefill.
    /// Strictly ahead of `queue` at admission time (a preempted request
    /// was already admitted once — letting newcomers starve it would
    /// turn preemption into a drop).
    resume: VecDeque<PreemptedSeq>,
    pub max_active: usize,
    pub max_queue: usize,
    /// Prompt tokens fed per tick per sequence during chunked prefill —
    /// each chunk is one whole-block batched kernel call.
    pub prefill_chunk: usize,
    /// Cap on sequences coalesced into one batched decode call; bounds
    /// the kernel's per-token LUT scratch (one TokenLut block each).
    pub max_decode_batch: usize,
    /// KV arena capacity in **f32-page equivalents** (the byte budget
    /// is this many f32 pages; quantized pages draw proportionally
    /// less of it).  `None` sizes the arena so every `max_active` slot
    /// can reach full context (no page pressure — the pre-arena
    /// behaviour); `Some(p)` lets the deployment commit less memory
    /// than the worst case and queue requests when bytes run short.
    pub kv_page_budget: Option<usize>,
    /// Self-speculative decode policy for the coalesced decode tick:
    /// `Some` makes every decode group draft with a low-bit slice mask
    /// and verify in one batched full-precision step (greedy outputs
    /// stay bit-identical; see `model::speculative`).  `None` keeps the
    /// plain one-token-per-tick decode.
    pub spec: Option<SpecConfig>,
    /// Host swap tier budget in bytes (0 disables the tier).  Sized
    /// in bytes — not f32-page equivalents like `kv_page_budget` —
    /// because host memory is a real external resource the deployment
    /// hands over; the scheduler converts it to whole f32-page slots
    /// when it sizes the arena.  When enabled, the pressure ladder's
    /// High/Critical rungs move cold KV pages here (exact byte
    /// copies) and preemption parks cold KV instead of dropping it.
    pub host_swap_bytes: usize,
    admitted: u64,
    rejected: u64,
    deferred: u64,
}

pub enum Admission {
    Queued,
    Rejected,
}

impl Batcher {
    pub fn new(max_active: usize, max_queue: usize) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            resume: VecDeque::new(),
            max_active,
            max_queue,
            prefill_chunk: 16,
            max_decode_batch: 32,
            kv_page_budget: None,
            spec: None,
            host_swap_bytes: 0,
            admitted: 0,
            rejected: 0,
            deferred: 0,
        }
    }

    /// Override the tick batching policy (values are clamped to >= 1).
    pub fn with_chunking(mut self, prefill_chunk: usize,
                         max_decode_batch: usize) -> Batcher {
        self.prefill_chunk = prefill_chunk.max(1);
        self.max_decode_batch = max_decode_batch.max(1);
        self
    }

    /// Commit an explicit KV page budget (see `kv_page_budget`).
    pub fn with_kv_budget(mut self, pages: usize) -> Batcher {
        self.kv_page_budget = Some(pages.max(1));
        self
    }

    /// Enable self-speculative decoding for the coalesced decode tick
    /// (see `spec`).
    pub fn with_speculative(mut self, cfg: SpecConfig) -> Batcher {
        self.spec = Some(cfg);
        self
    }

    /// Commit a host swap tier budget in bytes (see `host_swap_bytes`).
    pub fn with_host_swap(mut self, bytes: usize) -> Batcher {
        self.host_swap_bytes = bytes;
        self
    }

    pub fn submit(&mut self, req: Request) -> Admission {
        if self.queue.len() >= self.max_queue {
            self.rejected += 1;
            return Admission::Rejected;
        }
        self.queue.push_back(req);
        Admission::Queued
    }

    /// Pop as many requests as fit beside `n_active` running sequences
    /// (slot cap only — no budget accounting).
    pub fn admit(&mut self, n_active: usize) -> Vec<Request> {
        self.admit_with(n_active, usize::MAX, |_| 0)
    }

    /// Pop requests that fit beside `n_active` running sequences AND
    /// whose worst-case KV budget needs (computed by `need` — bytes on
    /// the serving path, accounting for the request's KV storage
    /// precision and any shared-prefix discount) fit in `free_budget`.
    /// Admission stays strictly FIFO: the first queued request that
    /// does not fit blocks the queue — later, smaller requests are not
    /// admitted around it (no starvation), and the deferral is
    /// counted.
    pub fn admit_with(&mut self, n_active: usize,
                      mut free_budget: usize,
                      mut need: impl FnMut(&Request) -> usize)
                      -> Vec<Request> {
        let mut out = Vec::new();
        while n_active + out.len() < self.max_active {
            let Some(front) = self.queue.front() else { break };
            let cost = need(front);
            if cost > free_budget {
                self.deferred += 1;
                break;
            }
            // the head just costed is popped here; a logic slip that
            // empties the queue in between must stop admission, not
            // panic the dispatcher thread
            let Some(req) = self.queue.pop_front() else { break };
            free_budget -= cost;
            out.push(req);
        }
        self.admitted += out.len() as u64;
        out
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Park a preempted sequence for a later resume.
    pub fn park(&mut self, p: PreemptedSeq) {
        self.resume.push_back(p);
    }

    /// The preempted sequence next in line to resume, if any.
    pub fn peek_resume(&self) -> Option<&PreemptedSeq> {
        self.resume.front()
    }

    pub fn pop_resume(&mut self) -> Option<PreemptedSeq> {
        self.resume.pop_front()
    }

    /// Preempted sequences waiting to resume.
    pub fn parked(&self) -> usize {
        self.resume.len()
    }

    /// The request next in line for admission, if any.
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Remove the queue head without admitting it — the scheduler uses
    /// this to reject a request whose worst-case KV bytes exceed the
    /// whole arena (it could never run and would deadlock the FIFO).
    pub fn drop_head(&mut self) -> Option<Request> {
        let r = self.queue.pop_front();
        if r.is_some() {
            self.rejected += 1;
        }
        r
    }

    pub fn queued_ids(&self) -> Vec<RequestId> {
        self.queue.iter().map(|r| r.id).collect()
    }

    pub fn counts(&self) -> (u64, u64) {
        (self.admitted, self.rejected)
    }

    /// Times admission stopped because the queue head's worst-case KV
    /// bytes did not fit the arena's free budget.
    pub fn deferred(&self) -> u64 {
        self.deferred
    }

    /// Queue pressure in [0, 1] — feeds the elastic controller.
    /// Parked (preempted) sequences count: they are queued work too.
    pub fn pressure(&self) -> f64 {
        (self.queue.len() + self.resume.len()) as f64
            / self.max_queue.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::property;
    use std::sync::mpsc;
    use std::time::Instant;

    fn mk_req(id: u64) -> (Request, mpsc::Receiver<super::super::request::Response>) {
        let (tx, rx) = mpsc::channel();
        (Request {
            id,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            kv_precision: crate::model::kvcache::KvPrecision::F32,
            submitted: Instant::now(),
            reply: tx,
        }, rx)
    }

    #[test]
    fn fifo_order_and_cap() {
        let mut b = Batcher::new(2, 100);
        let mut _rxs = Vec::new();
        for id in 0..5 {
            let (r, rx) = mk_req(id);
            _rxs.push(rx);
            b.submit(r);
        }
        let first = b.admit(0);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 1]);
        // one slot busy -> only one more admitted
        let second = b.admit(1);
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![2]);
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn rejects_when_full() {
        let mut b = Batcher::new(1, 2);
        let mut _rxs = Vec::new();
        let mut rejected = 0;
        for id in 0..5 {
            let (r, rx) = mk_req(id);
            _rxs.push(rx);
            if matches!(b.submit(r), Admission::Rejected) {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 3);
        assert_eq!(b.counts().1, 3);
    }

    #[test]
    fn paged_admission_defers_fifo() {
        let mut b = Batcher::new(8, 100);
        let mut _rxs = Vec::new();
        for id in 0..3 {
            let (r, rx) = mk_req(id);
            _rxs.push(rx);
            b.submit(r);
        }
        // each request "needs" 4 pages; 9 free pages admit only two
        let got = b.admit_with(0, 9, |_| 4);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 1]);
        assert_eq!(b.queued(), 1, "third request must stay queued");
        assert_eq!(b.deferred(), 1);
        // pages freed (retire) -> the blocked head admits
        let got = b.admit_with(2, 4, |_| 4);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![2]);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn no_loss_no_duplication() {
        property(77, 20, |rng, _| {
            let max_active = 1 + rng.below(4);
            let mut b = Batcher::new(max_active, 1000);
            let mut _rxs = Vec::new();
            let n = 20 + rng.below(30);
            for id in 0..n as u64 {
                let (r, rx) = mk_req(id);
                _rxs.push(rx);
                b.submit(r);
            }
            let mut seen = Vec::new();
            let mut active = 0usize;
            while seen.len() < n {
                let batch = b.admit(active);
                assert!(active + batch.len() <= max_active);
                for r in &batch {
                    seen.push(r.id);
                }
                active += batch.len();
                // randomly retire some
                let retire = rng.below(active + 1);
                active -= retire;
            }
            let mut sorted = seen.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted.len(), n, "lost or duplicated requests");
            // FIFO: seen must be sorted already
            assert_eq!(seen, {
                let mut s = seen.clone();
                s.sort();
                s
            });
        });
    }
}
