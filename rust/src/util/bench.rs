//! Bench harness (criterion is not vendored; `cargo bench` uses
//! `harness = false` targets built on this module).
//!
//! Pattern per paper table/figure:
//!
//! ```ignore
//! let mut suite = Suite::new("tab1_endtoend");
//! suite.bench("mobiq_2bit", || decode_row());   // timed
//! suite.row("PPL", &[("2bit", 10.9), ...]);     // computed metric rows
//! suite.finish();                               // prints + writes JSON
//! ```
//!
//! Timing uses warmup + fixed-duration sampling with median / MAD
//! reporting, which is robust on a noisy shared 1-core box.

use std::time::{Duration, Instant};

use super::json::{arr, num, obj, s, to_string, Value};
use super::stats;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

pub struct Suite {
    pub name: String,
    pub warmup: Duration,
    pub measure: Duration,
    results: Vec<BenchResult>,
    rows: Vec<(String, Vec<(String, f64)>)>,
    notes: Vec<String>,
    started: Instant,
}

impl Suite {
    pub fn new(name: &str) -> Self {
        let fast = std::env::var("MOBIQ_BENCH_FAST").is_ok();
        Suite {
            name: name.to_string(),
            warmup: if fast { Duration::from_millis(50) }
                    else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) }
                     else { Duration::from_millis(1200) },
            results: Vec::new(),
            rows: Vec::new(),
            notes: Vec::new(),
            started: Instant::now(),
        }
    }

    /// Time a closure; returns median ns/iter.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        // warmup + calibrate iters per sample
        let w0 = Instant::now();
        let mut calib_iters = 0u64;
        while w0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        // target ~2ms per sample
        let iters = ((2e6 / per_iter).ceil() as u64).max(1);
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure || samples.len() < 5 {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
            if samples.len() > 5000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            median_ns: stats::median(&samples),
            mean_ns: stats::mean(&samples),
            p10_ns: stats::percentile(&samples, 10.0),
            p90_ns: stats::percentile(&samples, 90.0),
            samples: samples.len(),
            iters_per_sample: iters,
        };
        let med = res.median_ns;
        println!(
            "  {:40} {:>12.1} ns/iter  (p10 {:.1}, p90 {:.1}, n={} x{})",
            name, med, res.p10_ns, res.p90_ns, res.samples, iters
        );
        self.results.push(res);
        med
    }

    /// Record a computed (non-timed) metric row, e.g. PPL per bit-width.
    pub fn row(&mut self, label: &str, cells: &[(&str, f64)]) {
        println!("  {:28} {}", label,
                 cells.iter().map(|(k, v)| format!("{}={:.4}", k, v))
                      .collect::<Vec<_>>().join("  "));
        self.rows.push((
            label.to_string(),
            cells.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    pub fn note(&mut self, text: &str) {
        println!("  # {}", text);
        self.notes.push(text.to_string());
    }

    pub fn header(&self) {
        println!("\n== {} ==", self.name);
    }

    /// Print summary and write `target/bench_reports/<name>.json`.
    pub fn finish(&self) {
        let results: Vec<Value> = self.results.iter().map(|r| {
            obj(vec![
                ("name", s(&r.name)),
                ("median_ns", num(r.median_ns)),
                ("mean_ns", num(r.mean_ns)),
                ("p10_ns", num(r.p10_ns)),
                ("p90_ns", num(r.p90_ns)),
                ("samples", num(r.samples as f64)),
            ])
        }).collect();
        let rows: Vec<Value> = self.rows.iter().map(|(label, cells)| {
            obj(vec![
                ("label", s(label)),
                ("cells", arr(cells.iter().map(|(k, v)| {
                    obj(vec![("k", s(k)), ("v", num(*v))])
                }).collect())),
            ])
        }).collect();
        let report = obj(vec![
            ("suite", s(&self.name)),
            ("elapsed_s", num(self.started.elapsed().as_secs_f64())),
            ("timings", arr(results)),
            ("rows", arr(rows)),
            ("notes", arr(self.notes.iter().map(|n| s(n)).collect())),
        ]);
        let dir = std::path::Path::new("target/bench_reports");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, to_string(&report)) {
            eprintln!("warn: could not write {}: {}", path.display(), e);
        }
        println!("== {} done in {:.1}s ==\n", self.name,
                 self.started.elapsed().as_secs_f64());
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("MOBIQ_BENCH_FAST", "1");
        let mut suite = Suite::new("selftest");
        let ns = suite.bench("noop_loop", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(ns > 0.0 && ns < 1e7);
    }

    #[test]
    fn rows_recorded() {
        let mut suite = Suite::new("selftest_rows");
        suite.row("ppl", &[("3bit", 6.07), ("4bit", 5.82)]);
        assert_eq!(suite.rows.len(), 1);
    }
}
